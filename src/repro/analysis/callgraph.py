"""Project index + jit-boundary call graph for the kvlint rules.

Name-based, flow-insensitive resolution tuned to this repo's idioms:

  * plain-name calls resolve within the defining file, then through
    explicit ``from module import name`` imports;
  * ``alias.func(...)`` resolves through module aliases
    (``from repro.core import paged_kv``  →  ``paged_kv.fill_layer``);
  * ``self.method(...)`` resolves in the enclosing class first, then to
    any same-named method project-wide (``self.engine.decode_step`` has
    no type information — method-name matching over-approximates, which
    is the safe direction for reachability).

Jit boundaries are ``jax.jit(...)`` / ``@jax.jit`` /
``@functools.partial(jax.jit, ...)`` sites; each records its wrapped
function, static/donated argument positions and — when the callable is
bound to a name (``self._decode = jax.jit(...)``) — the binding, so the
rules can find its call sites.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.analysis.core import FileCtx

# aliases that are never project modules — attribute calls on these are
# external and must not resolve by bare method name
EXTERNAL_BASES = {
    "jax", "jnp", "np", "numpy", "lax", "pl", "pltpu", "functools",
    "dataclasses", "math", "os", "sys", "time", "json", "re", "ast",
    "pytest", "hypothesis", "itertools", "collections", "asyncio",
    "logging", "struct", "random", "string", "textwrap",
}

JIT_NAMES = {"jax.jit", "jit", "pjit", "jax.pjit"}


def dotted(node: ast.AST) -> Optional[str]:
    """'a.b.c' for Name/Attribute chains, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


@dataclasses.dataclass(eq=False)   # identity hashing: one entry per def
class FuncInfo:
    qualname: str
    node: ast.AST                     # FunctionDef / AsyncFunctionDef / Lambda
    ctx: FileCtx
    params: List[str]
    is_method: bool
    class_name: Optional[str]

    @property
    def name(self) -> str:
        return self.qualname.rsplit(".", 1)[-1]

    @property
    def callable_params(self) -> List[str]:
        return self.params[1:] if self.is_method else self.params


@dataclasses.dataclass
class JitSite:
    call: ast.Call
    ctx: FileCtx
    target: Optional[FuncInfo]
    static_names: Set[str]
    static_nums: Set[int]
    donate_nums: Set[int]
    # how the jitted callable is addressed at call sites:
    #   ("attr", "_decode", "ContinuousBatcher") for self._decode = jit(..)
    #   ("name", "jitted", <file rel>)           for jitted = jit(..)
    #   ("def", "f", <file rel>)                 for @jit-decorated defs
    bound: Optional[Tuple[str, str, str]] = None


def _param_names(node: ast.AST) -> List[str]:
    a = node.args
    names = [p.arg for p in getattr(a, "posonlyargs", [])]
    names += [p.arg for p in a.args]
    names += [p.arg for p in a.kwonlyargs]
    return names


class ProjectIndex:
    def __init__(self, ctxs: Sequence[FileCtx]):
        self.ctxs = list(ctxs)
        self.funcs: List[FuncInfo] = []
        self.by_name: Dict[str, List[FuncInfo]] = {}
        self.by_node: Dict[ast.AST, FuncInfo] = {}
        # per-file: alias -> dotted module ("paged_kv" -> "repro.core.paged_kv")
        self.mod_aliases: Dict[str, Dict[str, str]] = {}
        # per-file: name -> (module, original name) from `from m import n`
        self.from_imports: Dict[str, Dict[str, Tuple[str, str]]] = {}
        self.module_of: Dict[str, str] = {}      # rel path -> module name
        self.jit_sites: List[JitSite] = []
        for ctx in self.ctxs:
            self._index_file(ctx)
        for ctx in self.ctxs:
            self._find_jit_sites(ctx)

    # -- indexing ------------------------------------------------------
    def _index_file(self, ctx: FileCtx):
        rel = ctx.rel
        mod = rel[:-3].replace("/", ".")
        for prefix in ("src.",):
            if mod.startswith(prefix):
                mod = mod[len(prefix):]
        if mod.endswith(".__init__"):
            mod = mod[: -len(".__init__")]
        self.module_of[rel] = mod
        aliases: Dict[str, str] = {}
        froms: Dict[str, Tuple[str, str]] = {}
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Import):
                for al in node.names:
                    aliases[al.asname or al.name.split(".")[0]] = al.name
            elif isinstance(node, ast.ImportFrom) and node.module:
                for al in node.names:
                    froms[al.asname or al.name] = (node.module, al.name)
                    # `from repro.core import paged_kv` imports a MODULE
                    aliases.setdefault(al.asname or al.name,
                                       f"{node.module}.{al.name}")
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._add_func(ctx, node)
        self.mod_aliases[rel] = aliases
        self.from_imports[rel] = froms

    def _add_func(self, ctx: FileCtx, node: ast.AST) -> FuncInfo:
        info = self.by_node.get(node)
        if info is not None:
            return info
        qual = ctx.qualname_of(node)
        cls = None
        parent = ctx.parents.get(node)
        if isinstance(parent, ast.ClassDef):
            cls = parent.name
        params = _param_names(node)
        is_method = cls is not None and bool(params) \
            and params[0] in ("self", "cls")
        info = FuncInfo(qual, node, ctx, params, is_method, cls)
        self.funcs.append(info)
        self.by_node[node] = info
        self.by_name.setdefault(info.name, []).append(info)
        return info

    def add_lambda(self, ctx: FileCtx, node: ast.Lambda) -> FuncInfo:
        info = self.by_node.get(node)
        if info is None:
            info = FuncInfo(ctx.qualname_of(node), node, ctx,
                            _param_names(node), False, None)
            self.funcs.append(info)
            self.by_node[node] = info
        return info

    # -- jit boundary discovery ----------------------------------------
    def _is_jit_expr(self, node: ast.AST) -> Optional[ast.Call]:
        """The jax.jit(...) Call for plain and functools.partial forms."""
        if not isinstance(node, ast.Call):
            return None
        d = dotted(node.func)
        if d in JIT_NAMES:
            return node
        if d in ("functools.partial", "partial") and node.args:
            if dotted(node.args[0]) in JIT_NAMES:
                return node
        return None

    def _find_jit_sites(self, ctx: FileCtx):
        for node in ast.walk(ctx.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for dec in node.decorator_list:
                    site = self._decorator_site(ctx, node, dec)
                    if site is not None:
                        self.jit_sites.append(site)
            call = self._is_jit_expr(node)
            if call is None or dotted(call.func) not in JIT_NAMES:
                continue
            site = self._call_site(ctx, call)
            if site is not None:
                self.jit_sites.append(site)

    def _extract_statics(self, call: ast.Call):
        static_names: Set[str] = set()
        static_nums: Set[int] = set()
        donate_nums: Set[int] = set()
        for kw in call.keywords:
            vals: List = []
            v = kw.value
            if isinstance(v, (ast.Tuple, ast.List)):
                vals = [getattr(e, "value", None) for e in v.elts]
            elif isinstance(v, ast.Constant):
                vals = [v.value]
            if kw.arg == "static_argnames":
                static_names.update(s for s in vals if isinstance(s, str))
            elif kw.arg == "static_argnums":
                static_nums.update(n for n in vals if isinstance(n, int))
            elif kw.arg == "donate_argnums":
                donate_nums.update(n for n in vals if isinstance(n, int))
        return static_names, static_nums, donate_nums

    def _decorator_site(self, ctx: FileCtx, fn: ast.AST,
                        dec: ast.AST) -> Optional[JitSite]:
        if dotted(dec) in JIT_NAMES:
            info = self._add_func(ctx, fn)
            return JitSite(None, ctx, info, set(), set(), set(),
                           bound=("def", fn.name, ctx.rel))
        call = self._is_jit_expr(dec)
        if call is None:
            return None
        sn, si, dn = self._extract_statics(call)
        info = self._add_func(ctx, fn)
        return JitSite(call, ctx, info, sn, si, dn,
                       bound=("def", fn.name, ctx.rel))

    def _call_site(self, ctx: FileCtx, call: ast.Call) -> Optional[JitSite]:
        sn, si, dn = self._extract_statics(call)
        target: Optional[FuncInfo] = None
        if call.args:
            arg0 = call.args[0]
            if isinstance(arg0, ast.Lambda):
                target = self.add_lambda(ctx, arg0)
            else:
                d = dotted(arg0)
                if d is not None:
                    cands = self.resolve(d, ctx, scope=call)
                    target = cands[0] if cands else None
        bound = None
        stmt = self.enclosing_stmt(ctx, call)
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                and stmt.value is not None:
            tgt = stmt.targets[0]
            if isinstance(tgt, ast.Attribute) \
                    and isinstance(tgt.value, ast.Name) \
                    and tgt.value.id == "self":
                cls = self._enclosing_class(ctx, call)
                bound = ("attr", tgt.attr, cls or ctx.rel)
            elif isinstance(tgt, ast.Name):
                bound = ("name", tgt.id, ctx.rel)
        return JitSite(call, ctx, target, sn, si, dn, bound=bound)

    # -- structural helpers --------------------------------------------
    def enclosing_stmt(self, ctx: FileCtx, node: ast.AST) -> ast.AST:
        cur = node
        while cur in ctx.parents and not isinstance(cur, ast.stmt):
            cur = ctx.parents[cur]
        return cur

    def enclosing_func(self, ctx: FileCtx,
                       node: ast.AST) -> Optional[FuncInfo]:
        cur: Optional[ast.AST] = ctx.parents.get(node)
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef,
                                ast.Lambda)):
                return self.by_node.get(cur)
            cur = ctx.parents.get(cur)
        return None

    def _enclosing_class(self, ctx: FileCtx,
                         node: ast.AST) -> Optional[str]:
        cur: Optional[ast.AST] = ctx.parents.get(node)
        while cur is not None:
            if isinstance(cur, ast.ClassDef):
                return cur.name
            cur = ctx.parents.get(cur)
        return None

    # -- call resolution -----------------------------------------------
    def resolve(self, name: str, ctx: FileCtx,
                scope: Optional[ast.AST] = None) -> List[FuncInfo]:
        """Candidate FuncInfos for a call spelled `name` in `ctx`."""
        parts = name.split(".")
        last = parts[-1]
        if len(parts) == 1:
            local = [f for f in self.by_name.get(last, ())
                     if f.ctx is ctx]
            if local:
                return local
            imp = self.from_imports.get(ctx.rel, {}).get(last)
            if imp is not None:
                mod, orig = imp
                # exact module match, or a package __init__ re-export
                # (def lives in a submodule of the imported package)
                return [f for f in self.by_name.get(orig, ())
                        if (self.module_of.get(f.ctx.rel) == mod
                            or self.module_of.get(f.ctx.rel, "")
                            .startswith(mod + "."))
                        and "." not in f.qualname]
            return []
        base = parts[0]
        if len(parts) == 2 and base not in ("self", "cls"):
            alias = self.mod_aliases.get(ctx.rel, {}).get(base)
            if alias is not None:
                hits = [f for f in self.by_name.get(last, ())
                        if self.module_of.get(f.ctx.rel) == alias
                        and "." not in f.qualname]
                if hits:
                    return hits
            if base in EXTERNAL_BASES:
                return []
        if base in EXTERNAL_BASES:
            return []
        if base in ("self", "cls") and len(parts) == 2:
            cls = self._enclosing_class(ctx, scope) if scope is not None \
                else None
            if cls is not None:
                own = [f for f in self.by_name.get(last, ())
                       if f.class_name == cls]
                if own:
                    return own
        # attribute call on an object of unknown type: every same-named
        # METHOD in the project (over-approximate reachability)
        return [f for f in self.by_name.get(last, ())
                if f.class_name is not None or base in ("self", "cls")]


def call_candidates(index: ProjectIndex, ctx: FileCtx,
                    call: ast.Call) -> List[FuncInfo]:
    d = dotted(call.func)
    if d is None:
        return []
    return index.resolve(d, ctx, scope=call)


def map_args_to_params(call: ast.Call, fn: FuncInfo,
                       via_attribute: bool) -> List[Tuple[str, ast.AST]]:
    """(param name, arg expr) pairs for a call site; bound-method calls
    (`obj.m(...)`) skip the receiver slot."""
    params = fn.callable_params if (fn.is_method and via_attribute) \
        else fn.params
    out: List[Tuple[str, ast.AST]] = []
    for i, arg in enumerate(call.args):
        if isinstance(arg, ast.Starred):
            break
        if i < len(params):
            out.append((params[i], arg))
    for kw in call.keywords:
        if kw.arg is not None and kw.arg in fn.params:
            out.append((kw.arg, kw.value))
    return out
