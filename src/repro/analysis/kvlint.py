"""kvlint CLI — ``python -m repro.analysis.kvlint src tests benchmarks``.

Exit status: 0 when every finding is suppressed or baselined, 1 when any
live finding remains, 2 on usage errors.  Text output is
``path:line:col: RULE message`` (clickable in CI logs); ``--format
json`` emits a machine-readable list.  ``--update-baseline`` rewrites
the baseline file with the current live findings (each entry then needs
a one-line justification in place of the TODO marker).
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import List

from repro.analysis.core import (RULES, Baseline, Finding, load_files,
                                 run_paths)

DEFAULT_BASELINE = "kvlint_baseline.txt"


def _root() -> Path:
    """Repo root = nearest ancestor of this file holding the baseline /
    ROADMAP, falling back to CWD (the CI invocation runs from the
    checkout root anyway)."""
    here = Path.cwd()
    for cand in (here, *here.parents):
        if (cand / "ROADMAP.md").exists() or (cand / ".git").exists():
            return cand
    return here


def main(argv: List[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.kvlint",
        description="KVNAND repo-specific static analyzer (KV001-KV005)")
    ap.add_argument("paths", nargs="+",
                    help="files or directories to analyze")
    ap.add_argument("--rules", default=",".join(RULES),
                    help="comma-separated rule subset (default: all)")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help=f"baseline file (default {DEFAULT_BASELINE}; "
                         "'none' disables)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="write current live findings to the baseline")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--root", default=None,
                    help="repo root override (tests)")
    args = ap.parse_args(argv)

    root = Path(args.root) if args.root else _root()
    rules = [r.strip() for r in args.rules.split(",") if r.strip()]
    unknown = [r for r in rules if r not in RULES]
    if unknown:
        print(f"kvlint: unknown rule(s) {unknown}; known: {RULES}",
              file=sys.stderr)
        return 2

    findings = run_paths(args.paths, root, rules)
    ctx_by_rel = {c.rel: c for c in load_files(args.paths, root)}

    bl_path = None if args.baseline == "none" \
        else (root / args.baseline)
    baseline = Baseline(bl_path)

    live: List[Finding] = []
    grandfathered: List[Finding] = []
    for f in findings:
        src = ctx_by_rel[f.path].src_line(f.line)
        (grandfathered if baseline.matches(f, src) else live).append(f)

    if args.update_baseline:
        if bl_path is None:
            print("kvlint: --update-baseline needs a baseline path",
                  file=sys.stderr)
            return 2
        lines = ["# kvlint baseline — grandfathered findings.",
                 "# One entry per line:  RULE path::qualname::crc  "
                 "justification",
                 "# Every entry MUST carry a one-line justification; "
                 "fix the finding instead when you can.", ""]
        for f in findings:
            src = ctx_by_rel[f.path].src_line(f.line)
            note = baseline.entries.get(
                f"{f.rule}:{f.key(src).split(':', 1)[1]}")
            lines.append(Baseline.format_entry(
                f, src, note or "TODO: justify this entry"))
        bl_path.write_text("\n".join(lines) + "\n")
        print(f"kvlint: wrote {len(findings)} entr"
              f"{'y' if len(findings) == 1 else 'ies'} to {bl_path}")
        return 0

    if args.format == "json":
        print(json.dumps([{
            "rule": f.rule, "path": f.path, "line": f.line,
            "col": f.col, "message": f.message, "qualname": f.qualname,
            "baselined": f in grandfathered,
        } for f in findings], indent=2))
    else:
        for f in live:
            print(f"{f.path}:{f.line}:{f.col + 1}: {f.rule} {f.message}")
        if grandfathered:
            print(f"kvlint: {len(grandfathered)} baselined finding(s) "
                  "suppressed")
        n = len(live)
        print(f"kvlint: {n} finding{'s' if n != 1 else ''} "
              f"({len(RULES) if args.rules == ','.join(RULES) else len(rules)}"
              f" rules over {len(ctx_by_rel)} files)")
    return 1 if live else 0


if __name__ == "__main__":
    sys.exit(main())
