"""KV001 jit purity · KV002 donation safety · KV003 recompile hazards.

All three rules share the jit-boundary call graph: KV001 walks functions
reachable from every ``jax.jit`` site with a fixpoint over which
parameters are traced; KV002/KV003 inspect the call sites of the bound
jitted callables themselves.
"""
from __future__ import annotations

import ast
from typing import Dict, FrozenSet, List, Optional, Set, Tuple

from repro.analysis.callgraph import (FuncInfo, JitSite, ProjectIndex,
                                      call_candidates, dotted,
                                      map_args_to_params)
from repro.analysis.core import FileCtx, Finding

# attribute reads on a traced array that are STATIC at trace time
STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "sharding", "at"}
HOST_CASTS = {"float", "int", "bool"}
NUMPY_PULLS = {"np.asarray", "np.array", "numpy.asarray", "numpy.array",
               "np.frombuffer", "onp.asarray", "onp.array"}


def _finding(ctx: FileCtx, node: ast.AST, rule: str, msg: str) -> Finding:
    return Finding(rule, ctx.rel, node.lineno, node.col_offset, msg,
                   ctx.qualname_of(node))


# ---------------------------------------------------------------------------
# traced-parameter fixpoint
# ---------------------------------------------------------------------------

def _seed_traced(site: JitSite) -> FrozenSet[str]:
    fn = site.target
    assert fn is not None
    params = fn.callable_params
    traced = [p for i, p in enumerate(params)
              if p not in site.static_names and i not in site.static_nums]
    if isinstance(fn.node, ast.Lambda):
        # `jax.jit(lambda q_, k_, quant=quant: ...)` — defaulted lambda
        # params are the Python default-capture idiom; they hold host
        # constants at trace time, not tracers
        a = fn.node.args
        captured = {p.arg for p in a.args[len(a.args) - len(a.defaults):]}
        captured |= {p.arg for p, d in zip(a.kwonlyargs, a.kw_defaults)
                     if d is not None}
        traced = [p for p in traced if p not in captured]
    return frozenset(traced)


def _names_in(expr: ast.AST) -> Set[str]:
    return {n.id for n in ast.walk(expr) if isinstance(n, ast.Name)}


def _flow_names(index: ProjectIndex, ctx: FileCtx, expr: ast.AST,
                ts: Set[str]) -> Set[str]:
    """Traced names whose tracedness FLOWS through `expr` into a callee
    argument.  Skips static accessors (`x.shape`, `len(x)`) and does not
    descend into calls to project functions — their return tracedness is
    unknown (e.g. `pool_page_count(cache.k_pages_g, ...)` returns a
    static page count), so assuming untraced avoids false positives;
    jnp/lax calls do propagate."""
    out: Set[str] = set()

    def visit(node: ast.AST):
        if isinstance(node, ast.Attribute) and node.attr in STATIC_ATTRS:
            return
        if isinstance(node, ast.Call):
            if isinstance(node.func, ast.Name) and node.func.id in (
                    "len", "isinstance", "type"):
                return
            d = dotted(node.func)
            if d is not None and index.resolve(d, ctx, scope=node):
                return
        if isinstance(node, ast.Name) and node.id in ts:
            out.add(node.id)
        for child in ast.iter_child_nodes(node):
            visit(child)

    visit(expr)
    return out


def propagate_traced(index: ProjectIndex) -> Dict[FuncInfo, Set[str]]:
    """Map every jit-reachable function to the set of its parameters that
    can carry tracers (flow over call edges until fixpoint)."""
    traced: Dict[FuncInfo, Set[str]] = {}
    work: List[FuncInfo] = []

    def absorb(fn: FuncInfo, names: FrozenSet[str]):
        cur = traced.get(fn)
        if cur is None:
            traced[fn] = set(names)
            work.append(fn)
        elif not names <= cur:
            cur |= names
            work.append(fn)

    for site in index.jit_sites:
        if site.target is not None:
            absorb(site.target, _seed_traced(site))
    while work:
        fn = work.pop()
        ts = traced[fn]
        for node in ast.walk(fn.node):
            if not isinstance(node, ast.Call):
                continue
            for cand in call_candidates(index, fn.ctx, node):
                via_attr = isinstance(node.func, ast.Attribute)
                pairs = map_args_to_params(node, cand, via_attr)
                hot = frozenset(p for p, arg in pairs
                                if _flow_names(index, fn.ctx, arg, ts))
                absorb(cand, hot)
    return traced


# ---------------------------------------------------------------------------
# KV001 — purity inside the traced scope
# ---------------------------------------------------------------------------

def _hazard_names(expr: ast.AST, ts: Set[str]) -> Set[str]:
    """Traced names used in `expr` in a way that concretizes them —
    skips `len(x)`, `x.shape/.ndim/.dtype/...` and `x is None` forms,
    all of which are static at trace time."""
    out: Set[str] = set()

    def visit(node: ast.AST):
        if isinstance(node, ast.Compare) and all(
                isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops) \
                and all(isinstance(c, ast.Constant) and c.value is None
                        for c in node.comparators):
            return
        if isinstance(node, ast.Compare) and all(
                isinstance(op, (ast.In, ast.NotIn)) for op in node.ops):
            # `"patches" in batch` — pytree/dict membership is a host-
            # level key check, static at trace time
            return
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
                and node.func.id in ("len", "isinstance", "getattr",
                                     "hasattr", "type"):
            return
        if isinstance(node, ast.Attribute) and node.attr in STATIC_ATTRS:
            return
        if isinstance(node, ast.Name) and node.id in ts:
            out.add(node.id)
        for child in ast.iter_child_nodes(node):
            visit(child)

    visit(expr)
    return out


def _scan_purity(index: ProjectIndex, fn: FuncInfo, ts: Set[str],
                 out: List[Finding]):
    ctx = fn.ctx

    def scan(node: ast.AST, ts: Set[str]):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)) and node is not fn.node:
            # nested function: runs at trace time; its own params shadow
            inner = ts - set(
                p.arg for p in list(node.args.args)
                + list(node.args.kwonlyargs)
                + list(getattr(node.args, "posonlyargs", [])))
            for child in ast.iter_child_nodes(node):
                scan(child, inner)
            return
        if isinstance(node, ast.Call):
            d = dotted(node.func)
            if isinstance(node.func, ast.Attribute) \
                    and node.func.attr == "item" and not node.args:
                out.append(_finding(
                    ctx, node, "KV001",
                    "`.item()` inside a jit-traced function forces a "
                    "host sync / fails under tracing — keep the value "
                    "on device or hoist it to the host caller"))
            elif d in ("jax.device_get", "device_get"):
                out.append(_finding(
                    ctx, node, "KV001",
                    "`jax.device_get` inside a jit-traced function — "
                    "device transfers belong to the host caller "
                    "(scheduler collect())"))
            elif d == "print":
                out.append(_finding(
                    ctx, node, "KV001",
                    "`print` inside a jit-traced function runs once at "
                    "trace time (or not at all) — use jax.debug.print "
                    "or remove"))
            elif d in NUMPY_PULLS and any(
                    _hazard_names(a, ts) for a in node.args):
                out.append(_finding(
                    ctx, node, "KV001",
                    f"`{d}` on a traced value materializes it on the "
                    "host (TracerArrayConversionError at best) — use "
                    "jnp instead"))
            elif isinstance(node.func, ast.Name) \
                    and node.func.id in HOST_CASTS and node.args and any(
                    _hazard_names(a, ts) for a in node.args):
                out.append(_finding(
                    ctx, node, "KV001",
                    f"`{node.func.id}()` on a traced value concretizes "
                    "it — keep the computation in jnp or mark the "
                    "argument static"))
        elif isinstance(node, (ast.If, ast.While)):
            bad = _hazard_names(node.test, ts)
            if bad:
                kind = "if" if isinstance(node, ast.If) else "while"
                out.append(_finding(
                    ctx, node, "KV001",
                    f"Python `{kind}` on traced value(s) "
                    f"{sorted(bad)} — branch at trace time is a "
                    "TracerBoolConversionError; use lax.cond/select "
                    "or make the argument static"))
        for child in ast.iter_child_nodes(node):
            scan(child, ts)

    body = fn.node.body if isinstance(fn.node.body, list) \
        else [fn.node.body]
    for stmt in body:
        scan(stmt, ts)


# ---------------------------------------------------------------------------
# KV002 — donated buffers are dead after the call
# ---------------------------------------------------------------------------

def _symbol_of(expr: ast.AST) -> Optional[str]:
    if isinstance(expr, ast.Name):
        return expr.id
    d = dotted(expr)
    if d is not None and d.count(".") == 1 and d.startswith("self."):
        return d
    return None


def _targets_of(stmt: ast.AST) -> Set[str]:
    """Symbols (re)bound by an assignment statement."""
    out: Set[str] = set()
    targets: List[ast.AST] = []
    if isinstance(stmt, ast.Assign):
        targets = list(stmt.targets)
    elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign, ast.For)):
        targets = [stmt.target]
    flat: List[ast.AST] = []
    while targets:
        t = targets.pop()
        if isinstance(t, (ast.Tuple, ast.List)):
            targets.extend(t.elts)
        else:
            flat.append(t)
    for t in flat:
        s = _symbol_of(t)
        if s is not None:
            out.add(s)
    return out


def _loads_in(node: ast.AST, symbol: str) -> List[ast.AST]:
    hits = []
    for n in ast.walk(node):
        if symbol.startswith("self."):
            if isinstance(n, ast.Attribute) \
                    and isinstance(n.ctx, ast.Load) \
                    and isinstance(n.value, ast.Name) \
                    and n.value.id == "self" \
                    and n.attr == symbol.split(".", 1)[1]:
                hits.append(n)
        elif isinstance(n, ast.Name) and n.id == symbol \
                and isinstance(n.ctx, ast.Load):
            hits.append(n)
    return hits


def _stmt_sequence_after(ctx: FileCtx, stmt: ast.AST,
                         stop: ast.AST) -> List[Tuple[str, ast.AST]]:
    """Statements that may execute after `stmt` inside `stop` (the
    enclosing function): later siblings at each nesting level walking
    outward, plus a loop re-entry pass for enclosing loops."""
    seq: List[Tuple[str, ast.AST]] = []
    child = stmt
    parent = ctx.parents.get(child)
    while parent is not None and child is not stop:
        for field in ("body", "orelse", "finalbody"):
            block = getattr(parent, field, None)
            if isinstance(block, list) and child in block:
                idx = block.index(child)
                for later in block[idx + 1:]:
                    seq.append(("after", later))
                if isinstance(parent, (ast.For, ast.While)) \
                        and field == "body":
                    for earlier in block[:idx + 1]:
                        seq.append(("reentry", earlier))
        child = parent
        parent = ctx.parents.get(child)
    return seq


def _check_donated_call(index: ProjectIndex, ctx: FileCtx, call: ast.Call,
                        site: JitSite, out: List[Finding]):
    fn = index.enclosing_func(ctx, call)
    if fn is None:
        return
    stmt = index.enclosing_stmt(ctx, call)
    rebound_here = _targets_of(stmt)
    for d in sorted(site.donate_nums):
        if d >= len(call.args):
            continue
        sym = _symbol_of(call.args[d])
        if sym is None or sym in rebound_here:
            continue                    # unpacked/rebound by this very stmt
        for phase, later in _stmt_sequence_after(ctx, stmt, fn.node):
            if phase == "reentry" and later is stmt:
                break                   # back at the call: next donation
            loads = _loads_in(later, sym)
            if loads:
                out.append(_finding(
                    ctx, loads[0], "KV002",
                    f"`{sym}` was donated (donate_argnums={d}) to the "
                    f"jitted callable at line {call.lineno} and read "
                    "again here — the buffer may already be aliased/"
                    "freed; rebind the result instead"))
                break
            if sym in _targets_of(later):
                break                   # rebound before any further read


# ---------------------------------------------------------------------------
# KV003 — one compiled signature per step callable
# ---------------------------------------------------------------------------

def _enclosing_loop(ctx: FileCtx, node: ast.AST) -> Optional[ast.AST]:
    cur = ctx.parents.get(node)
    while cur is not None and not isinstance(
            cur, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
        if isinstance(cur, (ast.For, ast.While)):
            return cur
        cur = ctx.parents.get(cur)
    return None


def _is_pylit(expr: ast.AST) -> bool:
    if isinstance(expr, ast.Constant) \
            and isinstance(expr.value, (int, float, bool)):
        return True
    return isinstance(expr, ast.UnaryOp) \
        and isinstance(expr.op, ast.USub) \
        and isinstance(expr.operand, ast.Constant)


def _bound_call_sites(index: ProjectIndex,
                      site: JitSite) -> List[Tuple[FileCtx, ast.Call]]:
    kind, name, where = site.bound
    hits: List[Tuple[FileCtx, ast.Call]] = []
    for ctx in index.ctxs:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            f = node.func
            if kind == "attr":
                if isinstance(f, ast.Attribute) and f.attr == name:
                    hits.append((ctx, node))
            elif isinstance(f, ast.Name) and f.id == name \
                    and ctx.rel == where:
                hits.append((ctx, node))
    return hits


def _check_recompile(index: ProjectIndex, site: JitSite,
                     out: List[Finding]):
    # (a) jit() minted inside a loop over a loop-invariant function
    if site.call is not None:
        loop = _enclosing_loop(site.ctx, site.call)
        if loop is not None and site.target is not None \
                and site.target.node.lineno < loop.lineno:
            out.append(_finding(
                site.ctx, site.call, "KV003",
                f"`jax.jit({site.target.name})` inside a loop mints a "
                "fresh callable (and a fresh compile cache) every "
                "iteration — hoist the jit out of the loop"))
    # (b) mixed Python-literal / array kinds at one traced position
    if site.bound is None:
        return
    sites = _bound_call_sites(index, site)
    if len(sites) < 2:
        return
    n_pos = max(len(c.args) for _, c in sites)
    for pos in range(n_pos):
        if pos in site.static_nums:
            continue
        kinds = []
        for ctx, call in sites:
            if pos < len(call.args):
                kinds.append((ctx, call, _is_pylit(call.args[pos])))
        lits = [t for t in kinds if t[2]]
        if lits and any(not t[2] for t in kinds):
            for ctx, call, _ in lits:
                out.append(_finding(
                    ctx, call.args[pos], "KV003",
                    f"Python scalar at traced position {pos} of jitted "
                    f"`{site.bound[1]}` while other call sites pass "
                    "arrays — the weak-typed scalar mints a second "
                    "compiled signature; pass a jnp array of the "
                    "step dtype"))


def _check_static_stability(index: ProjectIndex, site: JitSite,
                            out: List[Finding]):
    """static_argnames fed per-call-varying locals recompile per value."""
    if not site.static_names or site.bound is None \
            or site.target is None:
        return
    for ctx, call in _bound_call_sites(index, site):
        caller = index.enclosing_func(ctx, call)
        if caller is None:
            continue
        local_names = set(caller.params) - {"self", "cls"}
        for node in ast.walk(caller.node):
            if isinstance(node, ast.Assign):
                local_names |= _targets_of(node) | {
                    t.id for t in ast.walk(node)
                    if isinstance(t, ast.Name)
                    and isinstance(t.ctx, ast.Store)}
        pairs = map_args_to_params(call, site.target, False)
        for pname, arg in pairs:
            if pname not in site.static_names:
                continue
            risky = {n for n in _names_in(arg)
                     if n in local_names}
            if risky:
                out.append(_finding(
                    ctx, arg, "KV003",
                    f"static argument `{pname}` of jitted "
                    f"`{site.bound[1]}` is fed per-call-varying "
                    f"value(s) {sorted(risky)} — every distinct value "
                    "compiles a new signature; keep statics "
                    "config-derived or make the argument traced"))


# ---------------------------------------------------------------------------
# entry point
# ---------------------------------------------------------------------------

def check(index: ProjectIndex, selected: Set[str]) -> List[Finding]:
    findings: List[Finding] = []
    if "KV001" in selected:
        purity: List[Finding] = []
        for fn, ts in propagate_traced(index).items():
            _scan_purity(index, fn, ts, purity)
        seen = set()
        for f in purity:
            k = (f.path, f.line, f.col, f.message)
            if k not in seen:
                seen.add(k)
                findings.append(f)
    if "KV002" in selected:
        for site in index.jit_sites:
            if not site.donate_nums or site.bound is None:
                continue
            for ctx, call in _bound_call_sites(index, site):
                _check_donated_call(index, ctx, call, site, findings)
    if "KV003" in selected:
        for site in index.jit_sites:
            _check_recompile(index, site, findings)
            _check_static_stability(index, site, findings)
    return findings
