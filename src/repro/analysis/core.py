"""kvlint rule engine: findings, suppressions, baseline, orchestration.

Machinery only — the rules themselves live in ``rules_jit`` /
``rules_pool`` / ``rules_pallas``.  Everything here is stdlib-only so
the CI lint job can run without installing jax.
"""
from __future__ import annotations

import ast
import dataclasses
import re
import zlib
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set

RULES = ("KV001", "KV002", "KV003", "KV004", "KV005")

_SUPPRESS_RE = re.compile(r"#\s*kvlint:\s*disable=([A-Z0-9,\s]+)")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation, anchored to a source line.

    ``fingerprint`` keys baseline entries: it hashes the rule, the
    enclosing function's qualname and the *text* of the flagged line, so
    entries survive unrelated edits that renumber lines.
    """
    rule: str
    path: str          # repo-relative, forward slashes
    line: int          # 1-indexed
    col: int
    message: str
    qualname: str      # enclosing function ("<module>" at top level)

    @property
    def fingerprint(self) -> str:
        return f"{self.rule}:{self.path}::{self.qualname}"

    def key(self, src_line: str) -> str:
        crc = zlib.crc32(src_line.strip().encode())
        return f"{self.fingerprint}::{crc:08x}"


class FileCtx:
    """Parsed source file + per-line suppression map + parent links."""

    def __init__(self, path: Path, rel: str):
        self.path = path
        self.rel = rel
        self.source = path.read_text()
        self.lines = self.source.splitlines()
        self.tree = ast.parse(self.source, filename=rel)
        self.suppressed: Dict[int, Set[str]] = self._scan_suppressions()
        self.parents: Dict[ast.AST, ast.AST] = {}
        for node in ast.walk(self.tree):
            for child in ast.iter_child_nodes(node):
                self.parents[child] = node

    def _scan_suppressions(self) -> Dict[int, Set[str]]:
        """``# kvlint: disable=KV001[,KV002]`` suppresses its own line;
        a standalone suppression comment suppresses the next code line."""
        out: Dict[int, Set[str]] = {}
        for i, text in enumerate(self.lines, start=1):
            m = _SUPPRESS_RE.search(text)
            if not m:
                continue
            rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
            target = i
            if text.strip().startswith("#"):      # standalone comment line
                j = i + 1
                while j <= len(self.lines) and (
                        not self.lines[j - 1].strip()
                        or self.lines[j - 1].strip().startswith("#")):
                    j += 1
                target = j
            out.setdefault(target, set()).update(rules)
        return out

    def is_suppressed(self, rule: str, line: int) -> bool:
        return rule in self.suppressed.get(line, ())

    def src_line(self, line: int) -> str:
        if 1 <= line <= len(self.lines):
            return self.lines[line - 1]
        return ""

    def qualname_of(self, node: ast.AST) -> str:
        parts: List[str] = []
        cur: Optional[ast.AST] = node
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef,
                                ast.ClassDef)):
                parts.append(cur.name)
            elif isinstance(cur, ast.Lambda):
                parts.append("<lambda>")
            cur = self.parents.get(cur)
        return ".".join(reversed(parts)) or "<module>"


class Baseline:
    """Grandfathered findings: ``RULE path::qualname::crc  justification``
    per line.  A finding whose key matches an entry is reported only in
    verbose mode and never fails the run."""

    def __init__(self, path: Optional[Path]):
        self.path = path
        self.entries: Dict[str, str] = {}
        if path is not None and path.exists():
            for raw in path.read_text().splitlines():
                line = raw.strip()
                if not line or line.startswith("#"):
                    continue
                fields = line.split(None, 2)
                if len(fields) < 2:
                    continue
                rule, key = fields[0], fields[1]
                note = fields[2] if len(fields) > 2 else ""
                self.entries[f"{rule}:{key}"] = note

    def matches(self, finding: Finding, src_line: str) -> bool:
        rule_key = finding.key(src_line)
        # stored form: "RULE path::qual::crc"
        return f"{finding.rule}:{rule_key.split(':', 1)[1]}" in self.entries

    @staticmethod
    def format_entry(finding: Finding, src_line: str,
                     note: str = "TODO: justify this entry") -> str:
        key = finding.key(src_line)
        return f"{finding.rule} {key.split(':', 1)[1]}  {note}"


def iter_py_files(paths: Sequence[str], root: Path) -> List[Path]:
    files: List[Path] = []
    for p in paths:
        pp = (root / p) if not Path(p).is_absolute() else Path(p)
        if pp.is_dir():
            files.extend(sorted(f for f in pp.rglob("*.py")
                                if "__pycache__" not in f.parts))
        elif pp.suffix == ".py":
            files.append(pp)
    return files


def load_files(paths: Sequence[str], root: Path) -> List[FileCtx]:
    ctxs = []
    for f in iter_py_files(paths, root):
        try:
            rel = f.relative_to(root).as_posix()
        except ValueError:
            rel = f.as_posix()
        ctxs.append(FileCtx(f, rel))
    return ctxs


def run_paths(paths: Sequence[str], root: Path,
              rules: Optional[Iterable[str]] = None) -> List[Finding]:
    """Parse every .py under `paths`, run all rules, apply per-line
    suppressions (the baseline filter is the CLI's job)."""
    from repro.analysis import rules_jit, rules_pallas, rules_pool
    from repro.analysis.callgraph import ProjectIndex

    ctxs = load_files(paths, root)
    index = ProjectIndex(ctxs)
    selected = set(rules) if rules is not None else set(RULES)
    findings: List[Finding] = []
    if selected & {"KV001", "KV002", "KV003"}:
        findings += rules_jit.check(index, selected)
    if "KV004" in selected:
        findings += rules_pool.check(index)
    if "KV005" in selected:
        findings += rules_pallas.check(index)
    by_rel = {c.rel: c for c in ctxs}
    kept = [f for f in findings
            if not by_rel[f.path].is_suppressed(f.rule, f.line)]
    kept.sort(key=lambda f: (f.path, f.line, f.rule))
    return kept
