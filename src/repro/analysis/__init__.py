"""kvlint — repo-specific static analysis (stdlib `ast` only).

The rules prove, at the AST level, the correctness invariants the
KVNAND design makes load-bearing (DESIGN.md §15):

  KV001  jit purity — no host pulls / Python control flow on traced
         values inside functions reachable from a `jax.jit` boundary
  KV002  donation safety — a buffer passed at a `donate_argnums`
         position is never read again by the caller
  KV003  recompile hazards — nothing mints a second compiled signature
         on a jitted step callable
  KV004  pool-write discipline — every write to a cache pool leaf goes
         through the sentinel-gated writers in `core/paged_kv.py`
  KV005  Pallas kernel hygiene — pure index maps, declared
         `dimension_semantics`, side-effect-free kernel bodies

Run it with ``python -m repro.analysis.kvlint src tests benchmarks``.
This package intentionally imports no third-party code (no jax): the CI
lint job runs it on a bare interpreter.
"""
from repro.analysis.core import Finding, run_paths  # noqa: F401
