"""KV005 — Pallas kernel hygiene (files under ``kernels/``).

Three checks per ``pl.pallas_call`` site:

  * BlockSpec index maps must be pure functions of the grid indices
    (plus scalar-prefetch refs): closing over a parameter of the
    enclosing op function may capture a TRACED array — block addressing
    then silently depends on runtime data;
  * every multi-axis grid declares ``dimension_semantics`` (the
    parallel/arbitrary split is what lets the scratch-carrying page walk
    stay sequential while heads/partitions parallelize);
  * kernel bodies stay side-effect free: no ``print``/``open``, no host
    numpy — Refs in, Refs out.
"""
from __future__ import annotations

import ast
from typing import List, Optional, Set

from repro.analysis.callgraph import ProjectIndex, dotted
from repro.analysis.core import FileCtx, Finding

_GRID_SPEC_NAMES = {"pltpu.PrefetchScalarGridSpec", "PrefetchScalarGridSpec",
                    "pl.GridSpec", "GridSpec"}


def _enclosing_fn(ctx: FileCtx, node: ast.AST) -> Optional[ast.AST]:
    cur = ctx.parents.get(node)
    while cur is not None:
        if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return cur
        cur = ctx.parents.get(cur)
    return None


def _fn_params(fn: Optional[ast.AST]) -> Set[str]:
    if fn is None:
        return set()
    a = fn.args
    return {p.arg for p in list(a.args) + list(a.kwonlyargs)
            + list(getattr(a, "posonlyargs", []))}


def _lambda_free_names(lam: ast.Lambda) -> Set[str]:
    bound = {p.arg for p in list(lam.args.args)
             + list(lam.args.kwonlyargs)
             + list(getattr(lam.args, "posonlyargs", []))}
    if lam.args.vararg:
        bound.add(lam.args.vararg.arg)
    if lam.args.kwarg:
        bound.add(lam.args.kwarg.arg)
    return {n.id for n in ast.walk(lam.body)
            if isinstance(n, ast.Name)} - bound


def _tuple_lens(ctx: FileCtx, fn: Optional[ast.AST],
                expr: ast.AST) -> List[int]:
    """Possible lengths of a grid expression (tuple literals, following
    one level of local Name assignment)."""
    if isinstance(expr, ast.Tuple):
        return [len(expr.elts)]
    lens: List[int] = []
    if isinstance(expr, ast.Name) and fn is not None:
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign) and any(
                    isinstance(t, ast.Name) and t.id == expr.id
                    for t in node.targets) \
                    and isinstance(node.value, ast.Tuple):
                lens.append(len(node.value.elts))
    return lens


def _index_map_lambdas(ctx: FileCtx, fn: ast.AST) -> List[ast.Lambda]:
    """Lambdas appearing inside BlockSpec(...) calls within `fn`."""
    out = []
    for node in ast.walk(fn):
        if isinstance(node, ast.Call) and (dotted(node.func) or "") \
                .endswith("BlockSpec"):
            for sub in list(node.args) + [kw.value for kw in node.keywords]:
                if isinstance(sub, ast.Lambda):
                    out.append(sub)
    return out


def _kernel_body(ctx: FileCtx, index: ProjectIndex, fn: Optional[ast.AST],
                 expr: ast.AST) -> Optional[ast.AST]:
    """Resolve pallas_call's first argument to the kernel body def,
    following `kernel = functools.partial(_body, ...)` locals."""
    if isinstance(expr, ast.Name) and fn is not None:
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign) and any(
                    isinstance(t, ast.Name) and t.id == expr.id
                    for t in node.targets):
                v = node.value
                if isinstance(v, ast.Call) and (dotted(v.func) or "") in (
                        "functools.partial", "partial") and v.args:
                    expr = v.args[0]
                break
    d = dotted(expr)
    if d is None:
        return None
    cands = index.resolve(d, ctx)
    return cands[0].node if cands else None


def _scan_body_effects(ctx: FileCtx, body: ast.AST, out: List[Finding]):
    for node in ast.walk(body):
        bad = None
        if isinstance(node, ast.Call):
            d = dotted(node.func)
            if d in ("print", "open"):
                bad = f"`{d}()`"
        elif isinstance(node, ast.Name) and node.id in ("np", "numpy"):
            bad = f"host numpy (`{node.id}.`)"
        if bad is not None:
            out.append(Finding(
                "KV005", ctx.rel, node.lineno, node.col_offset,
                f"{bad} inside a Pallas kernel body — kernel bodies "
                "must be side-effect free (Refs in, Refs out; use "
                "jnp/lax/pl primitives only)",
                ctx.qualname_of(node)))


def check(index: ProjectIndex) -> List[Finding]:
    out: List[Finding] = []
    scanned_bodies = set()
    for ctx in index.ctxs:
        if "kernels/" not in ctx.rel:
            continue
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call) \
                    or (dotted(node.func) or "") \
                    .rsplit(".", 1)[-1] != "pallas_call":
                continue
            fn = _enclosing_fn(ctx, node)
            params = _fn_params(fn)

            # 1. index-map purity over every BlockSpec in this op
            if fn is not None:
                for lam in _index_map_lambdas(ctx, fn):
                    captured = sorted(_lambda_free_names(lam) & params)
                    if captured:
                        out.append(Finding(
                            "KV005", ctx.rel, lam.lineno, lam.col_offset,
                            f"BlockSpec index map closes over enclosing "
                            f"parameter(s) {captured} — index maps must "
                            "be pure functions of grid indices (and "
                            "scalar-prefetch refs); route runtime data "
                            "through scalar prefetch instead",
                            ctx.qualname_of(lam)))

            # 2. dimension_semantics on multi-axis grids
            grid_expr = None
            kwsrc: List[ast.AST] = [node]
            for kw in node.keywords:
                if kw.arg == "grid":
                    grid_expr = kw.value
                elif kw.arg == "grid_spec":
                    gs = kw.value
                    if isinstance(gs, ast.Name) and fn is not None:
                        for n2 in ast.walk(fn):
                            if isinstance(n2, ast.Assign) and any(
                                    isinstance(t, ast.Name)
                                    and t.id == gs.id
                                    for t in n2.targets):
                                gs = n2.value
                                break
                    if isinstance(gs, ast.Call) and (dotted(gs.func) or "") \
                            in _GRID_SPEC_NAMES:
                        kwsrc.append(gs)
                        for kw2 in gs.keywords:
                            if kw2.arg == "grid":
                                grid_expr = kw2.value
            if grid_expr is not None:
                lens = _tuple_lens(ctx, fn, grid_expr)
                has_sem = any(
                    isinstance(n2, ast.keyword)
                    and n2.arg == "dimension_semantics"
                    for src in kwsrc for n2 in ast.walk(src))
                if lens and max(lens) > 1 and not has_sem:
                    out.append(Finding(
                        "KV005", ctx.rel, node.lineno, node.col_offset,
                        f"pallas_call with a {max(lens)}-axis grid and "
                        "no `dimension_semantics` — declare the "
                        "parallel/arbitrary split (compiler_params=...) "
                        "so the sequential scratch walk is explicit",
                        ctx.qualname_of(node)))

            # 3. kernel-body purity
            if node.args:
                body = _kernel_body(ctx, index, fn, node.args[0])
                if body is not None and id(body) not in scanned_bodies:
                    scanned_bodies.add(id(body))
                    _scan_body_effects(ctx, body, out)
    return out
