"""KV004 — pool-write discipline.

Every write into a shared/striped KV pool leaf must go through the
sentinel-gated writer family in ``core/paged_kv.py`` (drop-sentinel
gating is what makes accept-gated span appends, chunk fills and COW
copies safe against stale/padding occupants — DESIGN.md §9/§11).  Any
direct ``leaf.at[...].set/add`` or ``dynamic_update_slice(leaf, ...)``
on a pool leaf in any other module is an error.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional

from repro.analysis.callgraph import ProjectIndex, dotted
from repro.analysis.core import FileCtx, Finding

# the KV pool data / scale / page-table leaves of DecodeCache (ring
# position rows `page_pos_w` and `lengths` are engine-owned metadata,
# not KV bytes, and stay out of scope)
POOL_LEAVES = {
    "k_pages", "v_pages", "k_scale", "v_scale",
    "k_pages_g", "v_pages_g", "k_scale_g", "v_scale_g",
    "k_pages_w", "v_pages_w", "k_scale_w", "v_scale_w",
    "page_table_g", "page_table_w",
}
# parameter names that conventionally carry a pool leaf in this repo
POOLISH_PARAMS = {"pool", "pools", "k_pages", "v_pages", "cache"}
ALLOWED_FILES = ("core/paged_kv.py",)

_DUS_NAMES = {"jax.lax.dynamic_update_slice", "lax.dynamic_update_slice",
              "dynamic_update_slice",
              "jax.lax.dynamic_update_slice_in_dim",
              "lax.dynamic_update_slice_in_dim"}


def _leafish(ctx: FileCtx, index: ProjectIndex, expr: ast.AST,
             fn_node: Optional[ast.AST]) -> Optional[str]:
    """Why `expr` denotes a pool leaf, or None.

    Catches: `cache.k_pages_g`, a local assigned from such an attribute,
    a local assigned from `getattr(cache_like, ...)` (the generic
    all-leaf writer idiom), and parameters named like a pool.
    """
    if isinstance(expr, ast.Attribute) and expr.attr in POOL_LEAVES:
        return f"cache leaf `.{expr.attr}`"
    if isinstance(expr, ast.Name):
        if fn_node is not None:
            args = fn_node.args
            params = {p.arg for p in list(args.args)
                      + list(args.kwonlyargs)
                      + list(getattr(args, "posonlyargs", []))}
            if expr.id in params and expr.id in POOLISH_PARAMS:
                return f"pool-carrying parameter `{expr.id}`"
            scope = fn_node
        else:
            scope = ctx.tree
        for node in ast.walk(scope):
            if not isinstance(node, ast.Assign):
                continue
            stores = {t.id for tgt in node.targets
                      for t in ast.walk(tgt)
                      if isinstance(t, ast.Name)
                      and isinstance(t.ctx, ast.Store)}
            if expr.id not in stores:
                continue
            for v in ast.walk(node.value):
                if isinstance(v, ast.Attribute) and v.attr in POOL_LEAVES:
                    return (f"local `{expr.id}` bound from cache leaf "
                            f"`.{v.attr}`")
                if isinstance(v, ast.Call) \
                        and dotted(v.func) == "getattr" and v.args:
                    base = dotted(v.args[0]) or ""
                    if base in ("cache", "c", "cur", "one", "self.cache",
                                "pool"):
                        return (f"local `{expr.id}` bound from "
                                f"getattr({base}, ...) over cache leaves")
    return None


def _enclosing_fn_node(ctx: FileCtx, node: ast.AST) -> Optional[ast.AST]:
    cur = ctx.parents.get(node)
    while cur is not None:
        if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef,
                            ast.Lambda)):
            return cur
        cur = ctx.parents.get(cur)
    return None


def check(index: ProjectIndex) -> List[Finding]:
    out: List[Finding] = []
    cache: Dict[int, Optional[str]] = {}
    for ctx in index.ctxs:
        if ctx.rel.endswith(ALLOWED_FILES):
            continue
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            base = why = None
            kind = None
            f = node.func
            # leaf.at[...].set(...) / .add(...)
            if isinstance(f, ast.Attribute) and f.attr in ("set", "add") \
                    and isinstance(f.value, ast.Subscript) \
                    and isinstance(f.value.value, ast.Attribute) \
                    and f.value.value.attr == "at":
                base = f.value.value.value
                kind = f".at[...].{f.attr}"
            elif dotted(f) in _DUS_NAMES and node.args:
                base = node.args[0]
                kind = "dynamic_update_slice"
            if base is None:
                continue
            key = id(base)
            if key not in cache:
                cache[key] = _leafish(ctx, index, base,
                                      _enclosing_fn_node(ctx, node))
            why = cache[key]
            if why is None:
                continue
            out.append(Finding(
                "KV004", ctx.rel, node.lineno, node.col_offset,
                f"direct {kind} on {why} outside core/paged_kv.py — "
                "every KV pool write must go through the sentinel-gated "
                "writers (append_*/fill_*/span/copy_page/stage/splice) "
                "so drop-gating and requant chains stay intact",
                ctx.qualname_of(node)))
    return out
