"""Fault-tolerant checkpointing: atomic, sharded, integrity-checked, keep-K.

Layout (one directory per step):
    ckpt_dir/step_000123.tmp-<nonce>/   (written first)
        arrays.npz        flat {path -> host array}
        manifest.json     {step, tree structure, shapes, sha256, wall time}
    ckpt_dir/step_000123/               (atomic rename when complete)

Restores are topology-agnostic: arrays land on host then get re-sharded to
whatever mesh the restarted job derives (elastic scaling — a checkpoint
written on 512 chips restores on 8).  A corrupted/partial checkpoint (bad
hash, missing file, interrupted rename) is skipped and the previous one is
used — `latest_step` only reports directories with a valid manifest.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


SEP = "/"


def _flatten_with_paths(tree) -> Dict[str, np.ndarray]:
    flat = {}

    def walk(prefix, node):
        if isinstance(node, dict):
            for k in sorted(node):
                walk(f"{prefix}{SEP}{k}" if prefix else str(k), node[k])
        elif hasattr(node, "_fields"):  # NamedTuple
            for k in node._fields:
                walk(f"{prefix}{SEP}{k}" if prefix else k,
                     getattr(node, k))
        elif node is None:
            flat[prefix + SEP + "__none__"] = np.zeros((), np.int8)
        else:
            arr = np.asarray(jax.device_get(node))
            if arr.dtype.name == "bfloat16":   # npz can't store ml_dtypes;
                arr = arr.astype(np.float32)   # f32 is lossless for bf16 and
            flat[prefix] = arr                 # restore re-casts via `like`

    walk("", tree)
    return flat


def save_checkpoint(ckpt_dir: str, step: int, tree, *, keep: int = 3,
                    extra: Optional[Dict[str, Any]] = None) -> str:
    """Atomic checkpoint write; prunes to the newest `keep` checkpoints."""
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten_with_paths(tree)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(prefix=f"step_{step:08d}.tmp-", dir=ckpt_dir)
    try:
        npz_path = os.path.join(tmp, "arrays.npz")
        np.savez(npz_path, **flat)
        with open(npz_path, "rb") as f:
            digest = hashlib.sha256(f.read()).hexdigest()
        manifest = {
            "step": step,
            "sha256": digest,
            "keys": sorted(flat.keys()),
            "shapes": {k: list(v.shape) for k, v in flat.items()},
            "dtypes": {k: str(v.dtype) for k, v in flat.items()},
            "time": time.time(),
        }
        if extra:
            manifest["extra"] = extra
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _prune(ckpt_dir, keep)
    return final


def _prune(ckpt_dir: str, keep: int):
    steps = sorted(list_steps(ckpt_dir))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)


def list_steps(ckpt_dir: str) -> List[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and ".tmp-" not in name:
            path = os.path.join(ckpt_dir, name, "manifest.json")
            if os.path.exists(path):
                try:
                    out.append(int(name[len("step_"):]))
                except ValueError:
                    pass
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    """Newest step whose checkpoint passes integrity validation."""
    for s in reversed(list_steps(ckpt_dir)):
        if validate_checkpoint(os.path.join(ckpt_dir, f"step_{s:08d}")):
            return s
    return None


def validate_checkpoint(path: str) -> bool:
    try:
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        with open(os.path.join(path, "arrays.npz"), "rb") as f:
            digest = hashlib.sha256(f.read()).hexdigest()
        return digest == manifest["sha256"]
    except (OSError, json.JSONDecodeError, KeyError):
        return False


def restore_checkpoint(ckpt_dir: str, step: int, like,
                       shardings=None) -> Tuple[Any, Dict[str, Any]]:
    """Restore into the structure of `like` (values replaced).

    `shardings`: optional matching pytree of NamedShardings — arrays are
    placed sharded (jax.device_put), so restore works on any mesh.
    """
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    if not validate_checkpoint(path):
        raise ValueError(f"checkpoint {path} failed integrity check")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))

    flat_like = _flatten_with_paths_structure(like)
    out_leaves = {}
    for key in flat_like:
        if key.endswith(SEP + "__none__"):
            continue
        arr = data[key]
        out_leaves[key] = arr

    def rebuild(prefix, node):
        if isinstance(node, dict):
            return {k: rebuild(f"{prefix}{SEP}{k}" if prefix else str(k), v)
                    for k, v in node.items()}
        if hasattr(node, "_fields"):
            return type(node)(*[
                rebuild(f"{prefix}{SEP}{k}" if prefix else k,
                        getattr(node, k)) for k in node._fields])
        if node is None:
            return None
        arr = out_leaves[prefix]
        return jnp.asarray(arr, dtype=node.dtype if hasattr(node, "dtype")
                           else None)

    tree = rebuild("", like)
    if shardings is not None:
        tree = jax.tree.map(
            lambda x, s: jax.device_put(x, s) if s is not None else x,
            tree, shardings, is_leaf=lambda x: x is None)
    return tree, manifest.get("extra", {})


def _flatten_with_paths_structure(tree) -> Dict[str, Any]:
    flat = {}

    def walk(prefix, node):
        if isinstance(node, dict):
            for k in sorted(node):
                walk(f"{prefix}{SEP}{k}" if prefix else str(k), node[k])
        elif hasattr(node, "_fields"):
            for k in node._fields:
                walk(f"{prefix}{SEP}{k}" if prefix else k, getattr(node, k))
        elif node is None:
            flat[prefix + SEP + "__none__"] = None
        else:
            flat[prefix] = node

    walk("", tree)
    return flat


class AsyncCheckpointer:
    """Background-thread checkpoint writer (training never blocks on I/O)."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_error: Optional[BaseException] = None

    def save(self, step: int, tree, extra=None):
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)

        def work():
            try:
                save_checkpoint(self.ckpt_dir, step, host_tree,
                                keep=self.keep, extra=extra)
            except BaseException as e:  # surfaced on next wait()
                self.last_error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err
