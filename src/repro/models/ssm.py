"""Selective SSM (Mamba-style) path — used by hymba's parallel heads.

Recurrence (per channel d, state dim N):
    h_t = exp(Δ_t A) ⊙ h_{t-1} + Δ_t B_t x_t
    y_t = C_t · h_t + D x_t ;  out = y ⊙ silu(z)

Training uses a chunked scan with an intra-chunk associative scan (memory
O(B·chunk·D·N) per step instead of O(B·S·D·N)); decode is an O(1) state
update.  A short causal conv (k=4) precedes the SSM as in Mamba.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import ParamBuilder, dense, init_dense

CONV_K = 4
CHUNK = 64


def init_ssm(b: ParamBuilder, cfg: ModelConfig):
    d, n = cfg.d_model, cfg.ssm_state
    init_dense(b, "in_x", d, d, ("embed", "heads"))
    init_dense(b, "in_z", d, d, ("embed", "heads"))
    b.param("conv_w", (CONV_K, d), (None, "ssm"), scale=0.5)
    b.param("conv_b", (d,), ("ssm",), init="zeros")
    init_dense(b, "w_b", d, n, ("embed", None))
    init_dense(b, "w_c", d, n, ("embed", None))
    init_dense(b, "w_dt", d, 1, ("embed", None), bias=True)
    b.param("a_log", (d, n), ("ssm", None), init="zeros")
    b.param("d_skip", (d,), ("ssm",), init="ones")
    init_dense(b, "out", d, d, ("heads", "embed"))


def ssm_state_shape(cfg: ModelConfig, batch: int) -> Tuple[int, ...]:
    """[B, D, N] SSM state (+ [B, CONV_K-1, D] conv tail carried separately)."""
    return (batch, cfg.d_model, cfg.ssm_state)


def _conv(p: Dict[str, Any], x: jax.Array, tail: jax.Array):
    """Causal depthwise conv; tail: [B, CONV_K-1, D] from previous segment."""
    xt = jnp.concatenate([tail.astype(x.dtype), x], axis=1)   # [B, S+K-1, D]
    w = p["conv_w"].astype(x.dtype)
    out = sum(xt[:, i:i + x.shape[1]] * w[i] for i in range(CONV_K))
    new_tail = xt[:, -(CONV_K - 1):] if CONV_K > 1 else tail
    return jax.nn.silu(out + p["conv_b"].astype(x.dtype)), new_tail


def _ssm_coeffs(p: Dict[str, Any], xc: jax.Array):
    """a_t = exp(Δ_t A) [B,S,D,N]; b_t = Δ_t B_t x_t [B,S,D,N]; C_t [B,S,N]."""
    A = -jnp.exp(p["a_log"].astype(jnp.float32))               # [D, N]
    dt = jax.nn.softplus(dense(p, "w_dt", xc).astype(jnp.float32))  # [B,S,1]
    Bt = dense(p, "w_b", xc).astype(jnp.float32)               # [B,S,N]
    Ct = dense(p, "w_c", xc).astype(jnp.float32)
    a = jnp.exp(dt[..., None] * A[None, None])                 # [B,S,D,N]
    bx = (dt * xc.astype(jnp.float32))[..., None] * Bt[:, :, None, :]
    return a, bx, Ct


def selective_scan_chunked(a, bx, C, h0, chunk: int = CHUNK):
    """h_t = a_t h_{t-1} + bx_t ; y_t = C_t · h_t.  Chunked associative scan."""
    B, S, D, N = a.shape
    pad = (-S) % chunk
    if pad:
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=1.0)
        bx = jnp.pad(bx, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    Sp = a.shape[1]
    nchunk = Sp // chunk
    a_c = a.reshape(B, nchunk, chunk, D, N).transpose(1, 0, 2, 3, 4)
    b_c = bx.reshape(B, nchunk, chunk, D, N).transpose(1, 0, 2, 3, 4)
    C_c = C.reshape(B, nchunk, chunk, N).transpose(1, 0, 2, 3)

    def chunk_step(h, inp):
        ac, bc, cc = inp                                       # [B,chunk,D,N]
        def combine(l, r):
            al, bl = l
            ar, br = r
            return al * ar, bl * ar + br
        acc_a, acc_b = jax.lax.associative_scan(combine, (ac, bc), axis=1)
        hs = acc_a * h[:, None] + acc_b                        # [B,chunk,D,N]
        y = jnp.einsum("bsdn,bsn->bsd", hs, cc)
        return hs[:, -1], y

    h_end, ys = jax.lax.scan(chunk_step, h0.astype(jnp.float32),
                             (a_c, b_c, C_c))
    y = ys.transpose(1, 0, 2, 3).reshape(B, Sp, D)[:, :S]
    return y, h_end


def ssm_mixer(p: Dict[str, Any], cfg: ModelConfig, x: jax.Array,
              state: jax.Array, conv_tail: jax.Array):
    """Full Mamba-style path. x: [B,S,D] -> (out, new_state, new_conv_tail)."""
    xz = dense(p, "in_z", x)
    xc = dense(p, "in_x", x)
    xc, new_tail = _conv(p, xc, conv_tail)
    a, bx, Ct = _ssm_coeffs(p, xc)
    y, h_end = selective_scan_chunked(a, bx, Ct, state)
    y = y + xc.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)
    out = dense(p, "out", (y.astype(x.dtype)) * jax.nn.silu(xz))
    return out, h_end, new_tail


def ssm_decode_step(p: Dict[str, Any], cfg: ModelConfig, x: jax.Array,
                    state: jax.Array, conv_tail: jax.Array):
    """Single-token O(1) update. x: [B,1,D]."""
    xz = dense(p, "in_z", x)
    xc = dense(p, "in_x", x)
    xc, new_tail = _conv(p, xc, conv_tail)
    a, bx, Ct = _ssm_coeffs(p, xc)
    h = a[:, 0] * state.astype(jnp.float32) + bx[:, 0]         # [B,D,N]
    y = jnp.einsum("bdn,bn->bd", h, Ct[:, 0])[:, None]
    y = y + xc.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)
    out = dense(p, "out", y.astype(x.dtype) * jax.nn.silu(xz))
    return out, h, new_tail
