"""Model facade: build a config-driven model with init / train / serve entry
points, plus `input_specs()` — ShapeDtypeStruct stand-ins for every input of
every (arch × shape) cell (the dry-run contract; no device allocation).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig, get_config
from repro.models import transformer
from repro.models.transformer import Runtime


class Model:
    """Thin, stateless facade over the functional model zoo."""

    def __init__(self, cfg: ModelConfig, rt: Optional[Runtime] = None):
        self.cfg = cfg
        self.rt = rt or Runtime()

    # -- params ---------------------------------------------------------
    def init(self, rng: jax.Array, dtype=jnp.float32):
        params, _ = transformer.init_model(self.cfg, rng, dtype)
        return params

    def init_with_specs(self, rng: jax.Array, dtype=jnp.float32):
        return transformer.init_model(self.cfg, rng, dtype)

    def abstract_params(self, dtype=jnp.float32):
        return transformer.abstract_params(self.cfg, dtype)

    # -- train ----------------------------------------------------------
    def loss(self, params, batch, remat: str = "none"):
        return transformer.loss_fn(params, self.cfg, batch, self.rt,
                                   remat=remat)

    def forward(self, params, batch, remat: str = "none"):
        return transformer.forward_train(params, self.cfg, batch, self.rt,
                                         remat=remat)


def build_model(arch: str, rt: Optional[Runtime] = None) -> Model:
    return Model(get_config(arch), rt)


# ---------------------------------------------------------------------------
# input_specs — dry-run stand-ins (weak-type-correct, shardable, no alloc)
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                rt: Optional[Runtime] = None,
                activ_dtype=jnp.bfloat16) -> Dict[str, Any]:
    """ShapeDtypeStructs for one (arch × shape) cell.

    train:   {tokens, labels}        [B, S] int32 (+ modality stubs)
    prefill: {tokens}                [B, S] int32 (+ modality stubs)
    decode:  {tokens}                [B, 1] int32 (+ cache built separately)
    """
    rt = rt or Runtime()
    B, S = shape.global_batch, shape.seq_len
    specs: Dict[str, Any] = {}

    if shape.kind == "train":
        s_tok = S
        if cfg.family == "vlm":
            s_tok = S - rt.vlm_patches
            specs["patches"] = _sds((B, rt.vlm_patches, cfg.d_model),
                                    activ_dtype)
        if cfg.n_meta_tokens:
            s_tok = S - cfg.n_meta_tokens
        if cfg.is_encoder_decoder:
            specs["frames"] = _sds((B, S // rt.enc_frames_ratio, cfg.d_model),
                                   activ_dtype)
        specs["tokens"] = _sds((B, s_tok), jnp.int32)
        specs["labels"] = _sds((B, s_tok), jnp.int32)
    elif shape.kind == "prefill":
        s_tok = S
        if cfg.family == "vlm":
            s_tok = S - rt.vlm_patches
            specs["patches"] = _sds((B, rt.vlm_patches, cfg.d_model),
                                    activ_dtype)
        if cfg.n_meta_tokens:
            s_tok = S - cfg.n_meta_tokens
        if cfg.is_encoder_decoder:
            specs["frames"] = _sds((B, S // rt.enc_frames_ratio, cfg.d_model),
                                   activ_dtype)
        specs["tokens"] = _sds((B, s_tok), jnp.int32)
    else:  # decode: one new token against a seq_len-deep cache
        specs["tokens"] = _sds((B, 1), jnp.int32)
        specs["positions"] = _sds((B,), jnp.int32)
    return specs


def batch_sharding_axes(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Tuple]:
    """Logical axes for each input (mapped to the mesh by sharding rules)."""
    axes: Dict[str, Tuple] = {}
    if shape.kind in ("train", "prefill"):
        axes["tokens"] = ("batch", None)
        if shape.kind == "train":
            axes["labels"] = ("batch", None)
        if cfg.family == "vlm":
            axes["patches"] = ("batch", None, None)
        if cfg.is_encoder_decoder:
            axes["frames"] = ("batch", None, None)
    else:
        axes["tokens"] = ("batch", None)
        axes["positions"] = ("batch",)
    return axes


def make_concrete_batch(cfg: ModelConfig, shape_or_specs, rng=None,
                        rt: Optional[Runtime] = None) -> Dict[str, jax.Array]:
    """Materialize a random batch matching input_specs (tests/examples)."""
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    specs = (shape_or_specs if isinstance(shape_or_specs, dict)
             else input_specs(cfg, shape_or_specs, rt))
    out = {}
    for name, sds in specs.items():
        rng, k = jax.random.split(rng)
        if jnp.issubdtype(sds.dtype, jnp.integer):
            out[name] = jax.random.randint(k, sds.shape, 0, cfg.vocab_size,
                                           sds.dtype)
        else:
            out[name] = jax.random.normal(k, sds.shape, jnp.float32).astype(
                sds.dtype)
    return out
