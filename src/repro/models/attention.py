"""Attention block: projections (+QKV bias), RoPE, flash-attention call.

Weight layout is *head-group-major*: wq [K, D, G·dh], wk/wv [K, D, dh]
(K = kv heads, G = q heads per group).  Head-group g's projection is a plain
index on the unsharded K dim — the KVNAND-D head-group pipeline slices
groups without touching the sharded feature dim (no resharding, no
all-gather of weights).  Head order is therefore kv-major (h = k·G + g),
which is exactly the GQA convention the kernels assume (kv head = h // G).

Exposes split phases (`project_qkv` / `project_out`) so the decode engine
can interpose the paged KV cache and the head-group pipeline between them.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels.flash_attention import flash_attention
from repro.models.layers import ParamBuilder, apply_rope, dense


def init_attention(b: ParamBuilder, cfg: ModelConfig, *, cross: bool = False):
    d = cfg.d_model
    K, G, dh = cfg.n_kv_heads, cfg.group_size, cfg.d_head
    b.param("wq_w", (K, d, G * dh), (None, "embed", "heads"))
    b.param("wk_w", (K, d, dh), (None, "embed", "head_dim"))
    b.param("wv_w", (K, d, dh), (None, "embed", "head_dim"))
    if cfg.attn_bias:
        b.param("wq_b", (K, G * dh), (None, "heads"), init="zeros")
        b.param("wk_b", (K, dh), (None, "head_dim"), init="zeros")
        b.param("wv_b", (K, dh), (None, "head_dim"), init="zeros")
    b.param("wo_w", (cfg.q_dim, d), ("heads", "embed"))


def _proj(p, name: str, x: jax.Array, dequant_fn=None) -> jax.Array:
    """x: [..., D] -> [..., K, f] via head-group-major weight."""
    w = p[f"{name}_w"]
    if type(w).__name__ == "QuantizedWeight":
        from repro.core.quant import dequantize
        w = dequantize(w, x.dtype)
    y = jnp.einsum("...d,kdf->...kf", x, w.astype(x.dtype))
    b = p.get(f"{name}_b")
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


def project_qkv(
    params: Dict[str, Any], cfg: ModelConfig, x: jax.Array,
    positions: Optional[jax.Array], *, rope: bool = True,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x: [B, S, D] -> q [B, S, H, dh], k/v [B, S, K, dh] (RoPE applied)."""
    B, S, _ = x.shape
    K, G, dh = cfg.n_kv_heads, cfg.group_size, cfg.d_head
    q = _proj(params, "wq", x).reshape(B, S, K * G, dh)
    k = _proj(params, "wk", x)                                 # [B, S, K, dh]
    v = _proj(params, "wv", x)
    if rope:
        if positions is None:
            positions = jnp.arange(S)[None, :]
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def project_q_group(params, cfg: ModelConfig, x_tok: jax.Array,
                    group: jax.Array, positions: jax.Array) -> jax.Array:
    """One head-group's q projection (the KVNAND-D pipelined GEMV).

    x_tok: [B, D] (single decode token); group: scalar index; returns
    [B, G, dh] roped at `positions` [B].
    """
    w = params["wq_w"]
    if type(w).__name__ == "QuantizedWeight":
        from repro.core.quant import dequantize
        w = dequantize(w, x_tok.dtype)
    wg = jax.lax.dynamic_index_in_dim(w, group, 0, keepdims=False)  # [D, G·dh]
    q = jnp.einsum("bd,df->bf", x_tok, wg.astype(x_tok.dtype))
    b = params.get("wq_b")
    if b is not None:
        q = q + jax.lax.dynamic_index_in_dim(b, group, 0,
                                             keepdims=False).astype(q.dtype)
    B = x_tok.shape[0]
    q = q.reshape(B, 1, cfg.group_size, cfg.d_head)
    return apply_rope(q, positions[:, None], cfg.rope_theta)[:, 0]


def project_out(params: Dict[str, Any], cfg: ModelConfig,
                attn: jax.Array) -> jax.Array:
    """attn: [B, S, H, dh] -> [B, S, D]."""
    B, S = attn.shape[:2]
    return dense(params, "wo", attn.reshape(B, S, cfg.q_dim))


def attention_train(
    params: Dict[str, Any], cfg: ModelConfig, x: jax.Array, *,
    window: Optional[int] = None, is_global=None, causal: bool = True,
    impl: str = "auto", positions: Optional[jax.Array] = None,
    kv_x: Optional[jax.Array] = None,
) -> jax.Array:
    """Full-sequence attention (train/prefill). kv_x enables cross-attention."""
    if kv_x is None:
        q, k, v = project_qkv(params, cfg, x, positions)
    else:  # cross-attention: queries from x, keys/values from encoder output
        B, S, _ = x.shape
        q = _proj(params, "wq", x).reshape(B, S, cfg.n_heads, cfg.d_head)
        k = _proj(params, "wk", kv_x)
        v = _proj(params, "wv", kv_x)
        causal = False
    out = sharded_flash_attention(q, k, v, causal=causal, window=window,
                                  is_global=is_global, impl=impl)
    return project_out(params, cfg, out)


def sharded_flash_attention(q, k, v, *, causal=True, window=None,
                            is_global=None, impl="auto"):
    """Mesh-adaptive attention: ring attention (sequence parallel) when the
    ambient mesh has a model axis > 1, single-device flash otherwise.

    Nesting-aware: inside an outer manual shard_map (the compressed-DP
    train step is manual over pod/data), the inner shard_map must use the
    abstract context mesh and may only map the still-Auto axes.
    """
    from repro.distributed.sharding import get_current_mesh
    mesh = get_current_mesh()
    manual = set()
    try:
        amesh = jax.sharding.get_abstract_mesh()
        if amesh is not None and not amesh.empty:
            manual = {n for n, t in zip(amesh.axis_names, amesh.axis_types)
                      if "Manual" in str(t)}
            if manual:
                mesh = amesh
    except Exception:
        pass
    if (mesh is not None and "model" in mesh.shape
            and mesh.shape["model"] > 1 and "model" not in manual
            and q.shape[1] % mesh.shape["model"] == 0
            and q.shape[1] > 1):
        from repro.core.seqpar import ring_attention
        batch_axes, rem = [], q.shape[0]
        for a in ("pod", "data"):
            if a in mesh.shape and a not in manual \
                    and rem % mesh.shape[a] == 0:
                batch_axes.append(a)
                rem //= mesh.shape[a]
        return ring_attention(q, k, v, mesh, causal=causal, window=window,
                              is_global=is_global,
                              batch_axes=tuple(batch_axes),
                              seq_axis="model")
    return flash_attention(q, k, v, causal=causal, window=window,
                           is_global=is_global, impl=impl)
