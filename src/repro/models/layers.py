"""Core layers and the ParamBuilder (params + logical-axis specs in one pass).

All parameters are plain pytrees (nested dicts of jnp arrays); a structurally
identical tree of logical-axis tuples is built alongside, which
`distributed.sharding` maps onto any mesh.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# ParamBuilder
# ---------------------------------------------------------------------------


class ParamBuilder:
    """Builds `params` and `specs` trees simultaneously.

    Works under `jax.eval_shape` for allocation-free abstract init (the
    dry-run path): all inits are jax PRNG ops, so tracing records shapes only.
    """

    def __init__(self, rng: jax.Array, dtype=jnp.float32):
        self._rng = rng
        self.dtype = dtype
        self.params: Dict[str, Any] = {}
        self.specs: Dict[str, Any] = {}

    def _next_key(self):
        self._rng, k = jax.random.split(self._rng)
        return k

    def param(self, name: str, shape: Sequence[int],
              axes: Sequence[Optional[str]], *, init: str = "normal",
              scale: Optional[float] = None, dtype=None) -> jax.Array:
        assert len(shape) == len(axes), (name, shape, axes)
        dtype = dtype or self.dtype
        if init == "zeros":
            val = jnp.zeros(shape, dtype)
        elif init == "ones":
            val = jnp.ones(shape, dtype)
        else:  # fan-in scaled normal
            if scale is None:
                fan_in = shape[0] if len(shape) == 1 else shape[-2]
                scale = 1.0 / math.sqrt(max(fan_in, 1))
            val = (jax.random.normal(self._next_key(), shape, jnp.float32)
                   * scale).astype(dtype)
        self.params[name] = val
        self.specs[name] = tuple(axes)
        return val

    def scope(self, name: str) -> "ParamBuilder":
        sub = ParamBuilder(self._next_key(), self.dtype)
        self.params[name] = sub.params
        self.specs[name] = sub.specs
        return sub


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def init_rms_norm(b: ParamBuilder, name: str, dim: int):
    b.param(name, (dim,), ("norm",), init="zeros")


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------

def rope_frequencies(d_head: int, theta: float) -> jax.Array:
    half = d_head // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., seq, heads, d_head]; positions: [..., seq] (int)."""
    d_head = x.shape[-1]
    freqs = rope_frequencies(d_head, theta)                  # [half]
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, half]
    cos = jnp.cos(angles)[..., :, None, :]                   # [..., S, 1, half]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Dense / MLP
# ---------------------------------------------------------------------------

def init_dense(b: ParamBuilder, name: str, in_dim: int, out_dim: int,
               axes: Tuple[Optional[str], Optional[str]], bias: bool = False):
    b.param(f"{name}_w", (in_dim, out_dim), axes)
    if bias:
        b.param(f"{name}_b", (out_dim,), (axes[1],), init="zeros")


def dense(params: Dict[str, Any], name: str, x: jax.Array) -> jax.Array:
    w = params[f"{name}_w"]
    if type(w).__name__ == "QuantizedWeight":
        from repro.kernels.quant_gemv import quant_gemv
        y = quant_gemv(x, w)
    else:
        y = jnp.einsum("...d,df->...f", x, w.astype(x.dtype))
    b = params.get(f"{name}_b")
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


def init_mlp(b: ParamBuilder, d_model: int, d_ff: int, gated: bool):
    if gated:
        init_dense(b, "gate", d_model, d_ff, ("embed", "mlp"))
        init_dense(b, "up", d_model, d_ff, ("embed", "mlp"))
    else:
        init_dense(b, "up", d_model, d_ff, ("embed", "mlp"))
    init_dense(b, "down", d_ff, d_model, ("mlp", "embed"))


def mlp(params: Dict[str, Any], x: jax.Array, gated: bool) -> jax.Array:
    if gated:
        h = jax.nn.silu(dense(params, "gate", x)) * dense(params, "up", x)
    else:
        h = jax.nn.gelu(dense(params, "up", x))
    return dense(params, "down", h)


def _maybe_dequant(w, dtype):
    if type(w).__name__ == "QuantizedWeight":
        from repro.core.quant import dequantize
        return dequantize(w, dtype)
    return w


# ---------------------------------------------------------------------------
# Mixture of Experts (expert-parallel, capacity-based dispatch)
# ---------------------------------------------------------------------------

def init_moe(b: ParamBuilder, d_model: int, d_ff: int, n_experts: int):
    b.param("router_w", (d_model, n_experts), ("embed", None))
    b.param("w_gate", (n_experts, d_model, d_ff), ("expert", "embed", "moe_mlp"))
    b.param("w_up", (n_experts, d_model, d_ff), ("expert", "embed", "moe_mlp"))
    b.param("w_down", (n_experts, d_ff, d_model), ("expert", "moe_mlp", "embed"))


def moe(params: Dict[str, Any], x: jax.Array, *, top_k: int,
        capacity_factor: float = 1.25) -> jax.Array:
    """Capacity-based top-k MoE with expert-parallel grouped matmuls.

    x: [B, S, D] -> [B, S, D].  Dispatch is *per batch row* so the dispatched
    buffer [B, E, C, D] shards over both data (B) and model (E) axes — at
    kimi-k2 scale (384 experts, 1M global tokens) a global dispatch buffer
    would not fit.  Position-within-expert uses a sort-based ranking
    (O(T·k) memory) instead of the classic one-hot cumsum (O(T·k·E)).
    Tokens beyond an expert's capacity are dropped (standard in EP training).
    """
    B, S, D = x.shape
    E = params["router_w"].shape[-1]
    T = S
    Tk = T * top_k
    C = max(1, math.ceil(capacity_factor * top_k * T / E))

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        params["router_w"].astype(jnp.float32))
    gates = jax.nn.softmax(logits, axis=-1)                        # [B, S, E]
    top_vals, top_idx = jax.lax.top_k(gates, top_k)                # [B, S, k]
    top_vals = top_vals / (jnp.sum(top_vals, -1, keepdims=True) + 1e-9)

    def route_row(xt, idx, vals):
        # xt: [T, D]; idx: [T, k]; vals: [T, k]
        fe = idx.reshape(-1)                                       # [Tk]
        order = jnp.argsort(fe, stable=True)
        counts = jnp.zeros((E,), jnp.int32).at[fe].add(1)
        starts = jnp.cumsum(counts) - counts                       # [E]
        pos_sorted = jnp.arange(Tk, dtype=jnp.int32) - starts[fe[order]]
        pos = jnp.zeros((Tk,), jnp.int32).at[order].set(pos_sorted)
        keep = pos < C

        tok_ids = jnp.repeat(jnp.arange(T, dtype=jnp.int32), top_k)
        slot = jnp.where(keep, fe * C + pos, E * C)                # drop -> OOB
        dispatched = jnp.zeros((E * C + 1, D), xt.dtype).at[slot].set(
            xt[tok_ids])[:-1].reshape(E, C, D)
        return dispatched, slot, keep, tok_ids

    xt = x  # [B, T, D]
    dispatched, slot, keep, tok_ids = jax.vmap(route_row)(
        xt, top_idx, top_vals)                                     # [B, E, C, D]

    # expert computation (grouped einsum; expert axis sharded -> EP)
    wg, wu, wd = (_maybe_dequant(params[k], x.dtype)
                  for k in ("w_gate", "w_up", "w_down"))
    h = (jax.nn.silu(jnp.einsum("becd,edf->becf", dispatched, wg.astype(x.dtype)))
         * jnp.einsum("becd,edf->becf", dispatched, wu.astype(x.dtype)))
    out = jnp.einsum("becf,efd->becd", h, wd.astype(x.dtype))      # [B, E, C, D]

    def combine_row(out_row, slot_row, keep_row, tok_row, vals):
        out_flat = out_row.reshape(E * C, D)
        safe = jnp.where(slot_row < E * C, slot_row, 0)
        gathered = jnp.where(keep_row[:, None], out_flat[safe], 0.0)
        weighted = gathered * vals.reshape(-1)[:, None].astype(out_flat.dtype)
        return jnp.zeros((T, D), out_flat.dtype).at[tok_row].add(weighted)

    combined = jax.vmap(combine_row)(out, slot, keep, tok_ids, top_vals)
    return combined.reshape(B, S, D)


def moe_aux_loss(params: Dict[str, Any], x: jax.Array, top_k: int) -> jax.Array:
    """Switch-style load-balancing auxiliary loss (fraction·prob product)."""
    E = params["router_w"].shape[-1]
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        params["router_w"].astype(jnp.float32))
    gates = jax.nn.softmax(logits, axis=-1)
    _, top_idx = jax.lax.top_k(gates, top_k)
    frac = jnp.mean(jax.nn.one_hot(top_idx, E, dtype=jnp.float32), axis=(0, 1, 2))
    prob = jnp.mean(gates, axis=(0, 1))
    return E * jnp.sum(frac * prob)


# ---------------------------------------------------------------------------
# Embedding / loss
# ---------------------------------------------------------------------------

def init_embedding(b: ParamBuilder, vocab: int, d_model: int,
                   name: str = "embedding"):
    # 1/sqrt(d) keeps tied-lm-head logits O(1) at init
    b.param(name, (vocab, d_model), ("vocab", "embed"),
            scale=d_model ** -0.5)


def embed_lookup(table: jax.Array, ids: jax.Array, dtype) -> jax.Array:
    return jnp.take(table, ids, axis=0).astype(dtype)


def cross_entropy_loss(logits: jax.Array, labels: jax.Array,
                       true_vocab: int) -> jax.Array:
    """Mean CE over labels >= 0, masking padded vocab entries."""
    V = logits.shape[-1]
    logits = logits.astype(jnp.float32)
    if true_vocab < V:
        neg = jnp.full((V - true_vocab,), -1e9, logits.dtype)
        logits = logits.at[..., true_vocab:].add(neg)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = logz - gold
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
