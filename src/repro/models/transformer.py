"""Unified model zoo: one stacked-layer decoder covering all six families.

Layers are *stacked* (leading `layer` axis on every per-layer param) and
executed with `lax.scan`, which keeps compile time flat in depth (61–80-layer
configs) — essential for the 40-cell dry-run.  Per-layer heterogeneity
(gemma3 local:global, hymba sparse-global) rides along as scanned boolean
flag arrays, not unrolled python branching.

Families:
  dense / moe / vlm : pre-norm attention + (SwiGLU | MoE) FFN
  ssm (rwkv6)       : time-mix (wkv) + channel-mix
  hybrid (hymba)    : parallel attention + mamba heads, averaged
  audio (whisper)   : bidirectional encoder + causal decoder w/ cross-attn
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import rwkv6, ssm
from repro.models.layers import (
    ParamBuilder, cross_entropy_loss, dense, embed_lookup, init_dense,
    init_embedding, init_mlp, init_moe, init_rms_norm, mlp, moe, moe_aux_loss,
    rms_norm,
)

# ---------------------------------------------------------------------------
# Runtime (static) knobs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Runtime:
    activ_dtype: Any = jnp.float32
    attn_impl: str = "auto"          # flash attention dispatch
    moe_capacity: float = 1.25
    vlm_patches: int = 256           # stub patch-prefix length (pixtral)
    enc_frames_ratio: int = 4        # whisper: frames = seq_len // ratio
    loss_chunk: int = 0              # >0: sequence-chunked CE (remat'd per
    #                                  chunk — one chunk of logits live at
    #                                  a time instead of [B, S, V])


# ---------------------------------------------------------------------------
# Initialization
# ---------------------------------------------------------------------------

def _init_block(b: ParamBuilder, cfg: ModelConfig):
    """One decoder block (params WITHOUT the layer axis; stacked by caller)."""
    init_rms_norm(b, "ln1", cfg.d_model)
    if cfg.family == "ssm":
        rwkv6.init_rwkv_timemix(b.scope("tmix"), cfg)
        init_rms_norm(b, "ln2", cfg.d_model)
        cm = b.scope("cmix")
        cm.param("mu_k", (cfg.d_model,), ("ssm",), init="zeros")
        cm.param("mu_r", (cfg.d_model,), ("ssm",), init="zeros")
        init_dense(cm, "ck", cfg.d_model, cfg.d_ff, ("embed", "mlp"))
        init_dense(cm, "cv", cfg.d_ff, cfg.d_model, ("mlp", "embed"))
        init_dense(cm, "cr", cfg.d_model, cfg.d_model, ("embed", "heads"))
        return
    attn_mod.init_attention(b.scope("attn"), cfg)
    if cfg.family == "hybrid":
        ssm.init_ssm(b.scope("ssm"), cfg)
    if cfg.is_encoder_decoder:
        init_rms_norm(b, "ln_cross", cfg.d_model)
        attn_mod.init_attention(b.scope("cross"), cfg, cross=True)
    init_rms_norm(b, "ln2", cfg.d_model)
    if cfg.is_moe:
        init_moe(b.scope("moe"), cfg.d_model, cfg.d_ff, cfg.n_experts)
    else:
        init_mlp(b.scope("mlp"), cfg.d_model, cfg.d_ff, cfg.gated_mlp)


def _init_stacked_layers(b: ParamBuilder, cfg: ModelConfig, n_layers: int,
                         name: str, encoder: bool = False):
    """Init `n_layers` blocks with a leading `layer` axis on every leaf.

    vmap over per-layer PRNG keys stacks every leaf while preserving each
    parameter's proper initializer (zeros/ones/fan-in normal).
    """
    cfg_blk = cfg if not encoder else dataclasses.replace(
        cfg, family="dense", is_encoder_decoder=False, n_kv_heads=cfg.n_heads)

    def one(key):
        pb = ParamBuilder(key, b.dtype)
        _init_block(pb, cfg_blk)
        return pb.params

    keys = jax.random.split(b._next_key(), n_layers)
    b.params[name] = jax.vmap(one)(keys)

    proto = ParamBuilder(jax.random.PRNGKey(0), b.dtype)
    _init_block(proto, cfg_blk)
    b.specs[name] = jax.tree.map(
        lambda sp: ("layer",) + tuple(sp), proto.specs,
        is_leaf=lambda x: isinstance(x, tuple))


def init_model(cfg: ModelConfig, rng: jax.Array, dtype=jnp.float32):
    """Returns (params, specs) — structurally identical trees."""
    b = ParamBuilder(rng, dtype)
    init_embedding(b, cfg.padded_vocab, cfg.d_model)
    _init_stacked_layers(b, cfg, cfg.n_layers, "layers")
    init_rms_norm(b, "final_norm", cfg.d_model)
    if not cfg.tie_embeddings:
        b.param("lm_head", (cfg.padded_vocab, cfg.d_model), ("vocab", "embed"))
    if cfg.is_encoder_decoder:
        _init_stacked_layers(b, cfg, cfg.encoder_layers, "encoder",
                             encoder=True)
        init_rms_norm(b, "encoder_norm", cfg.d_model)
    if cfg.n_meta_tokens:
        b.param("meta_tokens", (cfg.n_meta_tokens, cfg.d_model),
                (None, "embed"), scale=0.02)
    return b.params, b.specs


def abstract_params(cfg: ModelConfig, dtype=jnp.float32):
    """Allocation-free (ShapeDtypeStruct) params + specs, for the dry-run.

    The logical-axis spec tree is built by python side effects during the
    eval_shape trace, so no parameter memory is ever allocated.
    """
    holder = {}

    def f(k):
        params, specs = init_model(cfg, k, dtype)
        holder["specs"] = specs
        return params

    aparams = jax.eval_shape(f, jax.random.PRNGKey(0))
    return aparams, holder["specs"]


# layer-flag arrays (scanned along the layer axis)
def layer_flags(cfg: ModelConfig) -> Dict[str, jax.Array]:
    is_global = np.array([cfg.is_global_layer(i)
                          for i in range(cfg.n_layers)])
    return {"is_global": jnp.asarray(is_global)}


# ---------------------------------------------------------------------------
# Blocks (train / prefill — full sequence)
# ---------------------------------------------------------------------------

def _attn_ffn_block(pl_, cfg: ModelConfig, x, flags, rt: Runtime,
                    positions, enc_out=None):
    """Standard block; handles dense/moe/vlm/audio-decoder/hybrid."""
    window = None if cfg.window is None else cfg.window
    is_global = flags["is_global"] if cfg.window is not None else None
    h = rms_norm(x, pl_["ln1"], cfg.norm_eps)
    aout = attn_mod.attention_train(
        pl_["attn"], cfg, h, window=window, is_global=is_global,
        impl=rt.attn_impl, positions=positions)
    if cfg.family == "hybrid":
        B = x.shape[0]
        state0 = jnp.zeros(ssm.ssm_state_shape(cfg, B), jnp.float32)
        tail0 = jnp.zeros((B, ssm.CONV_K - 1, cfg.d_model), x.dtype)
        sout, _, _ = ssm.ssm_mixer(pl_["ssm"], cfg, h, state0, tail0)
        aout = (aout + sout) * 0.5
    x = x + aout
    if enc_out is not None:
        h = rms_norm(x, pl_["ln_cross"], cfg.norm_eps)
        x = x + attn_mod.attention_train(pl_["cross"], cfg, h, kv_x=enc_out,
                                         impl=rt.attn_impl)
    h = rms_norm(x, pl_["ln2"], cfg.norm_eps)
    if cfg.is_moe:
        ff = moe(pl_["moe"], h, top_k=cfg.top_k,
                 capacity_factor=rt.moe_capacity)
        aux = moe_aux_loss(pl_["moe"], h, cfg.top_k)
    else:
        ff = mlp(pl_["mlp"], h, cfg.gated_mlp)
        aux = jnp.zeros((), jnp.float32)
    return x + ff, aux


def _rwkv_block(pl_, cfg: ModelConfig, x, rt: Runtime):
    B = x.shape[0]
    h = rms_norm(x, pl_["ln1"], cfg.norm_eps)
    state0 = jnp.zeros(rwkv6.rwkv_state_shape(cfg, B), jnp.float32)
    shift0 = jnp.zeros((B, cfg.d_model), x.dtype)
    tout, _, _ = rwkv6.rwkv_timemix(pl_["tmix"], cfg, h, state0, shift0)
    x = x + tout
    h = rms_norm(x, pl_["ln2"], cfg.norm_eps)
    cm = pl_["cmix"]
    h_prev = jnp.concatenate([jnp.zeros_like(h[:, :1]), h[:, :-1]], axis=1)
    xk = h + (h_prev - h) * cm["mu_k"].astype(h.dtype)
    xr = h + (h_prev - h) * cm["mu_r"].astype(h.dtype)
    k = jnp.square(jax.nn.relu(dense(cm, "ck", xk)))
    v = dense(cm, "cv", k)
    r = jax.nn.sigmoid(dense(cm, "cr", xr))
    return x + r * v, jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# Full forward (train)
# ---------------------------------------------------------------------------

def embed_inputs(params, cfg: ModelConfig, batch: Dict[str, jax.Array],
                 rt: Runtime):
    """Builds the input activation sequence [B, S, D] + positions [B, S].

    vlm: [patch embeddings | token embeddings]; audio: decoder tokens only
    (encoder frames handled separately); hybrid: meta tokens prepended.
    """
    tok = batch["tokens"]
    x = embed_lookup(params["embedding"], tok, rt.activ_dtype)
    parts = [x]
    if cfg.family == "vlm" and "patches" in batch:
        parts.insert(0, batch["patches"].astype(rt.activ_dtype))
    if cfg.n_meta_tokens:
        B = tok.shape[0]
        meta = jnp.broadcast_to(
            params["meta_tokens"].astype(rt.activ_dtype)[None],
            (B, cfg.n_meta_tokens, cfg.d_model))
        parts.insert(0, meta)
    x = jnp.concatenate(parts, axis=1) if len(parts) > 1 else x
    positions = jnp.broadcast_to(jnp.arange(x.shape[1])[None],
                                 (x.shape[0], x.shape[1]))
    return x, positions


def run_layers(params, cfg: ModelConfig, x, rt: Runtime, positions,
               enc_out=None, remat: str = "none", stack: str = "layers",
               layer_constrain=None):
    """lax.scan over stacked layers; returns (x, aux_loss_sum).

    layer_constrain: optional fn applied to the sliced per-layer params
    INSIDE the (remat'd) body — used to gather ZeRO-3/fsdp shards one layer
    at a time.  Without it XLA hoists the all-gather of the ENTIRE stacked
    parameter array into the loop (measured 5.4 TB/device/step at kimi-k2
    scale) and all-reduces full-stack gradients per iteration.
    """
    flags = layer_flags(cfg)
    if stack == "encoder":
        flags = {"is_global": jnp.ones((cfg.encoder_layers,), bool)}

    def body(carry, layer_in):
        xc, aux = carry
        pl_, fl = layer_in
        if layer_constrain is not None:
            pl_, xc = layer_constrain(pl_, xc)
        if cfg.family == "ssm":
            xn, a = _rwkv_block(pl_, cfg, xc, rt)
        elif stack == "encoder":
            cfg_enc = dataclasses.replace(
                cfg, family="dense", is_encoder_decoder=False,
                n_kv_heads=cfg.n_heads)
            h = rms_norm(xc, pl_["ln1"], cfg.norm_eps)
            aout = attn_mod.attention_train(pl_["attn"], cfg_enc, h,
                                            causal=False, impl=rt.attn_impl,
                                            positions=positions)
            xc2 = xc + aout
            h = rms_norm(xc2, pl_["ln2"], cfg.norm_eps)
            xn = xc2 + mlp(pl_["mlp"], h, cfg.gated_mlp)
            a = jnp.zeros((), jnp.float32)
        else:
            xn, a = _attn_ffn_block(pl_, cfg, xc, fl, rt, positions, enc_out)
        return (xn, aux + a), None

    if remat in ("block", "full"):
        policy = None if remat == "full" else \
            jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        body = jax.checkpoint(body, policy=policy, prevent_cse=False)

    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                               (params[stack], flags))
    return x, aux


def lm_head_logits(params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    table = params.get("lm_head", params["embedding"])
    return jnp.einsum("bsd,vd->bsv", x, table.astype(x.dtype))


def forward_train(params, cfg: ModelConfig, batch: Dict[str, jax.Array],
                  rt: Runtime, remat: str = "none",
                  layer_constrain=None) -> Tuple[jax.Array, jax.Array]:
    """Full forward; returns (logits over the token positions, aux_loss)."""
    x, positions = embed_inputs(params, cfg, batch, rt)
    enc_out = None
    if cfg.is_encoder_decoder:
        enc = batch["frames"].astype(rt.activ_dtype)
        enc_pos = jnp.broadcast_to(jnp.arange(enc.shape[1])[None],
                                   enc.shape[:2])
        enc_out, _ = run_layers(params, cfg, enc, rt, enc_pos,
                                remat=remat, stack="encoder")
        enc_out = rms_norm(enc_out, params["encoder_norm"], cfg.norm_eps)
    x, aux = run_layers(params, cfg, x, rt, positions, enc_out, remat=remat,
                        layer_constrain=layer_constrain)
    # strip non-token prefixes (meta tokens / patches) before the LM head
    prefix = x.shape[1] - batch["tokens"].shape[1]
    if prefix:
        x = x[:, prefix:]
    return lm_head_logits(params, cfg, x), aux


def chunked_ce(params, cfg: ModelConfig, x: jax.Array, labels: jax.Array,
               chunk: int) -> jax.Array:
    """Sequence-chunked cross entropy: the LM head + softmax run one
    [B, chunk, V] block at a time under jax.checkpoint, so only a single
    chunk of logits is ever live (full [B, S, V] logits are the dominant
    train-step temp allocation at 150K–260K vocabs)."""
    B, S, D = x.shape
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    n = x.shape[1] // chunk
    xc = x.reshape(B, n, chunk, D).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, n, chunk).transpose(1, 0, 2)

    @functools.partial(jax.checkpoint, prevent_cse=False)
    def one(args):
        xch, lch = args
        logits = lm_head_logits(params, cfg, xch)
        V = logits.shape[-1]
        lg = logits.astype(jnp.float32)
        if cfg.vocab_size < V:
            neg = jnp.full((V - cfg.vocab_size,), -1e9, lg.dtype)
            lg = lg.at[..., cfg.vocab_size:].add(neg)
        logz = jax.nn.logsumexp(lg, axis=-1)
        gold = jnp.take_along_axis(
            lg, jnp.maximum(lch, 0)[..., None], axis=-1)[..., 0]
        mask = (lch >= 0).astype(jnp.float32)
        return jnp.sum((logz - gold) * mask), jnp.sum(mask)

    def body(carry, args):
        nll, cnt = one(args)
        return (carry[0] + nll, carry[1] + cnt), None

    (nll, cnt), _ = jax.lax.scan(body, (0.0, 0.0), (xc, lc))
    return nll / jnp.maximum(cnt, 1.0)


def loss_fn(params, cfg: ModelConfig, batch, rt: Runtime,
            remat: str = "none",
            layer_constrain=None) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """batch: tokens [B, S] (inputs) and labels [B, S] (pre-shifted)."""
    if rt.loss_chunk:
        x, positions = embed_inputs(params, cfg, batch, rt)
        enc_out = None
        if cfg.is_encoder_decoder:
            enc = batch["frames"].astype(rt.activ_dtype)
            enc_pos = jnp.broadcast_to(jnp.arange(enc.shape[1])[None],
                                       enc.shape[:2])
            enc_out, _ = run_layers(params, cfg, enc, rt, enc_pos,
                                    remat=remat, stack="encoder")
            enc_out = rms_norm(enc_out, params["encoder_norm"],
                               cfg.norm_eps)
        x, aux = run_layers(params, cfg, x, rt, positions, enc_out,
                            remat=remat, layer_constrain=layer_constrain)
        prefix = x.shape[1] - batch["tokens"].shape[1]
        if prefix:
            x = x[:, prefix:]
        ce = chunked_ce(params, cfg, x, batch["labels"], rt.loss_chunk)
        loss = ce + 0.01 * aux
        return loss, {"ce": ce, "aux": aux}
    logits, aux = forward_train(params, cfg, batch, rt, remat=remat,
                                layer_constrain=layer_constrain)
    ce = cross_entropy_loss(logits, batch["labels"], cfg.vocab_size)
    loss = ce + 0.01 * aux
    return loss, {"ce": ce, "aux": aux}
