"""RWKV6 (Finch) — attention-free token mixing with data-dependent decay.

Faithful structure: data-dependent token-shift (LoRA-modulated lerp), per-
channel data-dependent decay w_t, bonus u, multi-head wkv state
S ∈ [H, dh_k, dh_v], gated output with group norm.

Numerical adaptation (documented in DESIGN.md): the log-decay is bounded to
(-4.05, -0.05) via a sigmoid so the *chunked* parallel form (cumulative-
product factorization, chunk=32) is overflow-free in fp32.  The recurrent
oracle uses the same decay, so chunked == recurrent exactly (tested).

wkv recurrence (per head, per step):
    out_t = r_t · (S_{t-1} + u ⊙ k_t v_tᵀ)
    S_t   = diag(w_t) S_{t-1} + k_t v_tᵀ
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import ParamBuilder, dense, init_dense

LORA_RANK = 32
CHUNK = 32


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_rwkv_timemix(b: ParamBuilder, cfg: ModelConfig):
    d = cfg.d_model
    H, dh = cfg.n_heads, cfg.d_head
    # data-dependent token shift: base lerp factors + low-rank modulation
    b.param("mu_base", (5, d), (None, "ssm"), init="zeros")   # r,k,v,g,w
    b.param("mu_x", (d,), ("ssm",), init="zeros")
    b.param("lora_a", (d, LORA_RANK), ("embed", None), scale=0.01)
    b.param("lora_b", (LORA_RANK, 5, d), (None, None, "ssm"), scale=0.01)
    # decay + bonus
    b.param("w0", (d,), ("ssm",), init="zeros")
    b.param("wlora_a", (d, LORA_RANK), ("embed", None), scale=0.01)
    b.param("wlora_b", (LORA_RANK, d), (None, "ssm"), scale=0.01)
    b.param("u", (H, dh), (None, "ssm"), scale=0.5)
    # projections
    init_dense(b, "wr", d, d, ("embed", "heads"))
    init_dense(b, "wk", d, d, ("embed", "heads"))
    init_dense(b, "wv", d, d, ("embed", "heads"))
    init_dense(b, "wg", d, d, ("embed", "heads"))
    init_dense(b, "wo", d, d, ("heads", "embed"))
    b.param("ln_scale", (d,), ("norm",), init="ones")  # post-wkv group norm


def rwkv_state_shape(cfg: ModelConfig, batch: int) -> Tuple[int, ...]:
    """Per-layer recurrent state: [B, H, dh_k, dh_v] (+ shift token [B, D])."""
    return (batch, cfg.n_heads, cfg.d_head, cfg.d_head)


# ---------------------------------------------------------------------------
# shared projections
# ---------------------------------------------------------------------------

def _mix_inputs(p: Dict[str, Any], x: jax.Array, x_prev: jax.Array):
    """Data-dependent lerp between current and shifted token (5 streams)."""
    xx = x_prev - x                                           # [B, S, D]
    xmix = x + xx * p["mu_x"].astype(x.dtype)
    lora = jnp.tanh(xmix @ p["lora_a"].astype(x.dtype))       # [B, S, R]
    deltas = jnp.einsum("bsr,rcd->bcsd", lora,
                        p["lora_b"].astype(x.dtype))          # [B, 5, S, D]
    mus = p["mu_base"].astype(x.dtype)[None, :, None, :] + deltas
    mixed = x[:, None] + xx[:, None] * mus                    # [B, 5, S, D]
    return [mixed[:, i] for i in range(5)]                    # r,k,v,g,w


def _decay(p: Dict[str, Any], xw: jax.Array) -> jax.Array:
    """Bounded per-channel log-decay in (-4.05, -0.05) (see module doc)."""
    dw = jnp.tanh(xw @ p["wlora_a"].astype(xw.dtype)) @ \
        p["wlora_b"].astype(xw.dtype)
    logw = -0.05 - 4.0 * jax.nn.sigmoid(
        p["w0"].astype(jnp.float32) + dw.astype(jnp.float32))
    return logw                                               # [B, S, D]


def _project_rkvg(p, cfg, xr, xk, xv, xg):
    B, S, _ = xr.shape
    H, dh = cfg.n_heads, cfg.d_head
    r = dense(p, "wr", xr).reshape(B, S, H, dh)
    k = dense(p, "wk", xk).reshape(B, S, H, dh)
    v = dense(p, "wv", xv).reshape(B, S, H, dh)
    g = jax.nn.silu(dense(p, "wg", xg))
    return r, k, v, g


def _group_norm(x: jax.Array, scale: jax.Array, H: int) -> jax.Array:
    """Per-head layer norm of the wkv output ([B, S, H*dh])."""
    B, S, D = x.shape
    xh = x.reshape(B, S, H, D // H).astype(jnp.float32)
    mean = xh.mean(-1, keepdims=True)
    var = xh.var(-1, keepdims=True)
    xh = (xh - mean) * jax.lax.rsqrt(var + 1e-5)
    return (xh.reshape(B, S, D) * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# wkv core: recurrent oracle + chunked parallel form
# ---------------------------------------------------------------------------

def wkv_recurrent(r, k, v, logw, u, state):
    """Token-by-token scan (oracle + decode path).

    r,k,v: [B, S, H, dh]; logw: [B, S, H, dh] (per k-channel);
    u: [H, dh]; state: [B, H, dh, dh].  Returns (out [B,S,H,dh], state).
    """
    rf, kf, vf = (a.astype(jnp.float32) for a in (r, k, v))

    def step(S0, inp):
        rt, kt, vt, lw = inp                                   # [B, H, dh]
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        out = jnp.einsum("bhk,bhkv->bhv", rt, S0 + u[None, :, :, None] * kv)
        S1 = jnp.exp(lw)[..., None] * S0 + kv
        return S1, out

    xs = tuple(a.transpose(1, 0, 2, 3) for a in
               (rf, kf, vf, logw.astype(jnp.float32)))
    state, outs = jax.lax.scan(step, state.astype(jnp.float32), xs)
    return outs.transpose(1, 0, 2, 3).astype(r.dtype), state


def wkv_chunked(r, k, v, logw, u, state, chunk: int = CHUNK):
    """Chunked parallel form (cumprod factorization); == recurrent."""
    B, S, H, dh = r.shape
    pad = (-S) % chunk
    if pad:
        zp = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v, logw = zp(r), zp(k), zp(v), zp(logw)
    Sp = r.shape[1]
    n = Sp // chunk
    shp = (B, n, chunk, H, dh)
    rf, kf, vf, lw = (a.astype(jnp.float32).reshape(shp)
                      for a in (r, k, v, logw))

    # cumulative log-decay within chunk; a_t = exp(cum_t) (exclusive)
    cum = jnp.cumsum(lw, axis=2)                              # inclusive
    cum_excl = cum - lw                                        # exclusive
    total = cum[:, :, -1]                                      # [B, n, H, dh]

    r_a = rf * jnp.exp(cum_excl)                               # r_t · a_t
    k_b = kf * jnp.exp(-cum)                                   # k_i / (a_i w_i)
    k_last = kf * jnp.exp(total[:, :, None] - cum)             # for state update

    # intra-chunk attention-like term: A[t,i] = (r_t a_t)·(k_i e^{-cum_i}), i<t
    A = jnp.einsum("bnthd,bnihd->bnhti", r_a, k_b)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
    A = jnp.where(tri[None, None, None], A, 0.0)
    intra = jnp.einsum("bnhti,bnihd->bnthd", A, vf)
    # bonus term (current token through u)
    diag = jnp.einsum("bnthk,hk,bnthk->bnth", rf, u, kf)
    intra = intra + diag[..., None] * vf

    # inter-chunk: out += (r_t a_t) S_chunk_start
    def scan_chunks(S0, inp):
        ra_c, kb_last_c, v_c, tot_c = inp
        inter = jnp.einsum("bthk,bhkv->bthv", ra_c, S0)
        kv = jnp.einsum("bthk,bthv->bhkv", kb_last_c, v_c)
        S1 = jnp.exp(tot_c)[..., None] * S0 + kv
        return S1, inter

    xs = (r_a.transpose(1, 0, 2, 3, 4), k_last.transpose(1, 0, 2, 3, 4),
          vf.transpose(1, 0, 2, 3, 4), total.transpose(1, 0, 2, 3))
    state, inters = jax.lax.scan(scan_chunks, state.astype(jnp.float32), xs)
    out = intra + inters.transpose(1, 0, 2, 3, 4)
    out = out.reshape(B, Sp, H, dh)[:, :S]
    return out.astype(r.dtype), state


# ---------------------------------------------------------------------------
# full time-mix layer
# ---------------------------------------------------------------------------

def rwkv_timemix(p: Dict[str, Any], cfg: ModelConfig, x: jax.Array,
                 state: jax.Array, shift: jax.Array, *,
                 chunked: bool = True):
    """x: [B,S,D]; state: [B,H,dh,dh]; shift: [B,D] (previous last token).

    Returns (out [B,S,D], new_state, new_shift).
    """
    B, S, D = x.shape
    H = cfg.n_heads
    x_prev = jnp.concatenate([shift[:, None], x[:, :-1]], axis=1)
    xr, xk, xv, xg, xw = _mix_inputs(p, x, x_prev)
    r, k, v, g = _project_rkvg(p, cfg, xr, xk, xv, xg)
    logw = _decay(p, xw).reshape(B, S, H, cfg.d_head)
    u = p["u"].astype(jnp.float32)

    if chunked and S > 1:
        from repro.kernels.wkv6 import wkv6  # lazy: kernels re-export ref
        out, state = wkv6(r, k, v, logw, u, state)  # pallas on TPU
    else:
        out, state = wkv_recurrent(r, k, v, logw, u, state)
    out = _group_norm(out.reshape(B, S, D), p["ln_scale"], H)
    out = dense(p, "wo", out * g)
    return out, state, x[:, -1]
